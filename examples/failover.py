"""Tenant failover demo — rogue tenant detected, quarantined, evicted, and
its partition reclaimed for a new tenant, while co-tenants never miss a
launch.

Drives the fault-containment subsystem (DESIGN.md §Fault-containment)
end-to-end:

1. three tenants share a CHECK-policy manager; launches fuse into one
   device step per drain cycle with per-row ok attribution,
2. tenant "rogue" starts issuing out-of-bounds writes — the fused step
   rolls its rows back on device and folds per-kind counts into the
   ViolationLog, co-tenant rows keep landing,
3. the QuarantineManager's cycle-boundary poll crosses the threshold:
   rogue is QUARANTINED (queued ops dropped, new calls rejected),
4. the operator evicts it: partition scrubbed (verified zeroed) and
   returned to the buddy allocator, compiled symbol-cache entries purged,
5. a new tenant registers and is admitted into the freed block.

    PYTHONPATH=src python examples/failover.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    FencePolicy,
    GuardianManager,
    QuarantineError,
    TenantQuarantined,
    TenantState,
    ThresholdPolicy,
)

TOTAL = 1 << 10


def work(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def oob_write(arena, target, n):
    idx = target + jnp.arange(n, dtype=jnp.int32)
    return arena.at[idx].set(666.0), None


def main():
    mgr = GuardianManager(
        total_slots=TOTAL, policy=FencePolicy.CHECK,
        quarantine_policy=ThresholdPolicy(quarantine_after=12))

    print("1) three tenants share the arena (CHECK policy, fused drains)")
    names = ["alice", "bob", "rogue"]
    clients, ptrs = {}, {}
    for name in names:
        c = mgr.register_tenant(name, TOTAL // 8)
        c.module_load("work", work)
        c.module_load("oob", oob_write)
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        clients[name], ptrs[name] = c, p
    mgr.synchronize()
    for name in names:
        part = mgr.bounds.lookup(name)
        print(f"   {name:6s} slots [{part.base}, {part.end})")
    rogue_part = mgr.bounds.lookup("rogue")

    print("2) rogue goes out of bounds; co-tenants keep launching")
    victim = mgr.bounds.lookup("alice")
    for cycle in range(6):
        for name in ("alice", "bob"):
            clients[name].launch_kernel("work", ptrs=[ptrs[name]], args=(8,))
        if mgr.quarantine.state_of("rogue").admissible:
            clients["rogue"].launch_kernel(
                "oob", args=(jnp.int32(victim.base), 8))
    mgr.synchronize()

    report = mgr.violation_report()
    print(f"   violation report: {report['tenants']}")
    assert report["tenants"]["rogue"]["scatter"] >= 12
    assert report["tenants"]["alice"]["total"] == 0
    alice_data = clients["alice"].memcpy_d2h(ptrs["alice"], 8)
    assert (alice_data == 6.0).all(), alice_data   # all 6 cycles landed
    print(f"   alice's writes all landed: {alice_data[:4]}...")

    print("3) rogue was quarantined at the cycle boundary")
    assert mgr.quarantine.state_of("rogue") is TenantState.QUARANTINED
    try:
        clients["rogue"].launch_kernel("work", ptrs=[ptrs["rogue"]],
                                       args=(8,))
        raise AssertionError("quarantined launch was admitted")
    except TenantQuarantined as e:
        print(f"   new launch rejected: {e}")

    print("4) evict: partition scrubbed + reclaimed, caches purged")
    free_before = mgr.bounds.free_slots()
    mgr.quarantine.evict("rogue")
    scrubbed = np.asarray(mgr.arena.unsafe_read_range(
        rogue_part.base, rogue_part.size))
    assert (scrubbed == 0).all()
    print(f"   slots [{rogue_part.base}, {rogue_part.end}) zeroed, "
          f"free {free_before} -> {mgr.bounds.free_slots()}")
    try:
        mgr.register_tenant("rogue", TOTAL // 8)
    except QuarantineError as e:
        print(f"   re-registration refused: {e}")
    else:
        raise AssertionError("EVICTED id re-registered without readmit")

    print("5) new tenant admitted into the freed block")
    c_new = mgr.register_tenant("carol", TOTAL // 8)
    new_part = mgr.bounds.lookup("carol")
    assert new_part.base == rogue_part.base, (new_part, rogue_part)
    p_new = c_new.malloc(8)
    c_new.memcpy_h2d(p_new, np.full(8, 3.0, np.float32))
    c_new.launch_kernel("work", ptrs=[p_new], args=(8,))
    mgr.synchronize()
    np.testing.assert_array_equal(c_new.memcpy_d2h(p_new, 8),
                                  np.full(8, 4.0, np.float32))
    print(f"   carol reuses slots [{new_part.base}, {new_part.end}); "
          "co-tenant service never stopped.\nall good.")


if __name__ == "__main__":
    main()
