"""Multi-tenant LM serving with Guardian isolation.

Three tenants share one model server and one KV page pool.  The engine
carves pow2 slot partitions per tenant; every batched decode step fences
each row's slot ids with its tenant's (base, mask).  The demo shows:

1. normal co-located serving (round-robin batching across tenants),
2. that a tenant's generations are bit-identical whether or not other
   tenants are co-located (no cross-tenant interference),
3. a forged-slot attack bouncing off the fence.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""

import numpy as np

from repro.configs import get_config
from repro.launch.serve import ServeEngine


def main():
    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(7)
    prompts = {f"tenant{i}": rng.integers(0, cfg.vocab, 12).astype(
        np.int32) for i in range(3)}

    print("=== co-located serving (3 tenants, shared pool) ===")
    eng = ServeEngine(cfg, max_batch=8, max_len=128)
    parts = {}
    for t in prompts:
        parts[t] = eng.register_tenant(t, 2)
        print(f"  {t}: slots [{parts[t].base}, {parts[t].end})  "
              f"mask={parts[t].mask:#x}")
    rids = {t: eng.submit(t, p) for t, p in prompts.items()}
    out = eng.run(max_new_tokens=10)
    for t, rid in rids.items():
        print(f"  {t}: {out[rid]}")

    print("\n=== isolation: tenant0 alone vs co-located ===")
    solo = ServeEngine(cfg, max_batch=8, max_len=128)
    solo.register_tenant("tenant0", 2)
    rid = solo.submit("tenant0", prompts["tenant0"])
    solo_out = solo.run(max_new_tokens=10)[rid]
    same = solo_out == out[rids["tenant0"]]
    print(f"  identical generations: {same}")
    assert same

    print("\n=== forged slot id bounces off the fence ===")
    eng2 = ServeEngine(cfg, max_batch=8, max_len=128)
    vp = eng2.register_tenant("victim", 4)
    eng2.register_tenant("attacker", 4)
    rv = eng2.submit("victim", prompts["tenant0"])
    eng2.run(max_new_tokens=4)
    before = np.asarray(eng2.cache.k[:, vp.base:vp.end]).copy()
    ra = eng2.submit("attacker", prompts["tenant1"])
    req = [r for r in eng2._requests if r.rid == ra][0]
    req.slot = vp.base      # scheduler compromise!
    eng2.run(max_new_tokens=4)
    after = np.asarray(eng2.cache.k[:, vp.base:vp.end])
    print(f"  victim KV rows changed: {bool((before != after).any())} "
          "(fence wrapped the attack into the attacker's partition)")
    assert (before == after).all()
    print("\nall good.")


if __name__ == "__main__":
    main()
