"""Quickstart — Guardian in 60 seconds.

Two mutually-untrusting tenants share one device arena.  Tenant B runs an
adversarial kernel aimed straight at tenant A's buffer; the bitwise fence
wraps the attack into B's own partition.  Then the same workloads run in
all three bounds modes to show the cost ladder.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    FencePolicy,
    GuardianManager,
    GuardianViolation,
    SharingMode,
)


def main():
    print("=" * 64)
    print("1. Two tenants, one device arena, bitwise fencing")
    print("=" * 64)
    mgr = GuardianManager(total_slots=4096, policy=FencePolicy.BITWISE,
                          mode=SharingMode.TIME_SHARE)
    alice = mgr.register_tenant("alice", 1024)
    bob = mgr.register_tenant("bob", 1024)

    secret = alice.malloc(16)
    alice.memcpy_h2d(secret, np.full(16, 42.0, np.float32))
    alice.synchronize()

    # Bob registers a kernel that writes 666 at an arbitrary address —
    # the sandboxer fences the store at "PTX level" (jaxpr level here).
    def evil(arena, target, n):
        idx = target + jnp.arange(n, dtype=jnp.int32)
        return arena.at[idx].set(666.0), None

    bob.module_load("evil", evil)
    print(f"bob attacks alice's buffer at slot {secret.addr} ...")
    bob.launch_kernel("evil", args=(jnp.int32(secret.addr), 16))
    bob.synchronize()
    got = alice.memcpy_d2h(secret, 16)
    print(f"alice's data after the attack: {got[:4]} (unchanged: "
          f"{bool((got == 42.0).all())})")
    bob_part = mgr.bounds.lookup("bob")
    bob_mem = np.asarray(mgr.arena.unsafe_read_range(bob_part.base,
                                                     bob_part.size))
    print(f"the 666s wrapped into bob's own partition: "
          f"{int((bob_mem == 666.0).sum())} slots hit\n")

    # host-initiated transfers are range-checked at the manager
    import dataclasses
    forged = dataclasses.replace(secret)
    try:
        bob.memcpy_d2h(forged, 16)
    except GuardianViolation as e:
        print(f"2. forged-pointer memcpy rejected:\n   {e}\n")

    print("=" * 64)
    print("3. The three bounds modes (cost ladder, honest workload)")
    print("=" * 64)

    def saxpy(arena, x_ptr, y_ptr, n):
        ii = jnp.arange(n, dtype=jnp.int32)
        x = jnp.take(arena, x_ptr + ii, axis=0)
        y = jnp.take(arena, y_ptr + ii, axis=0)
        return arena.at[y_ptr + ii].set(2.0 * x + y), None

    for policy in (FencePolicy.NONE, FencePolicy.BITWISE,
                   FencePolicy.MODULO, FencePolicy.CHECK):
        m2 = GuardianManager(total_slots=4096, policy=policy,
                             mode=SharingMode.TIME_SHARE,
                             standalone_fast_path=False)
        t1 = m2.register_tenant("t1", 1024)
        m2.register_tenant("t2", 1024)
        x = t1.malloc(256)
        y = t1.malloc(256)
        t1.memcpy_h2d(x, np.ones(256, np.float32))
        t1.memcpy_h2d(y, np.zeros(256, np.float32))
        t1.module_load("saxpy", saxpy)
        t1.launch_kernel("saxpy", ptrs=[x, y], args=(256,))  # warm
        t1.synchronize()
        t0 = time.perf_counter()
        for _ in range(50):
            t1.launch_kernel("saxpy", ptrs=[x, y], args=(256,))
        t1.synchronize()
        dt = (time.perf_counter() - t0) / 50
        print(f"   {policy.value:8s}: {dt * 1e6:7.1f} us/launch")


if __name__ == "__main__":
    main()
