"""Preemption/resume demo — kill training mid-run, restart, bit-exact
convergence.

(Formerly examples/failover.py, which now demonstrates tenant failover —
quarantine + partition reclamation; this file keeps the checkpoint/resume
restart-exact contract covered.)

Simulates a node preemption by killing the training process between
steps, then restarts from the atomic checkpoint with ``--resume`` and
verifies the final loss matches an uninterrupted run (the restart-exact
contract of the deterministic data pipeline + atomic checkpoints).

    PYTHONPATH=src python examples/preemption_resume.py
"""

import json
import os
import shutil
import subprocess
import sys

ENV = {**os.environ, "PYTHONPATH": "src"}


def run_train(steps, ckpt_dir, resume=False, stop_after=0,
              timeout=1200):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "minicpm-2b", "--reduced", "--steps", str(steps),
           "--batch", "4", "--seq", "64", "--lr", "3e-3",
           "--ckpt-dir", ckpt_dir, "--ckpt-every", "20",
           "--log-every", "20"]
    if resume:
        cmd.append("--resume")
    if stop_after:
        cmd += ["--stop-after", str(stop_after)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=ENV,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-1500:]
    last = [ln for ln in r.stdout.splitlines() if ln.startswith("{")][-1]
    return json.loads(last)


def main():
    base = "/tmp/guardian_failover"
    shutil.rmtree(base, ignore_errors=True)

    print("1) uninterrupted run: 60 steps")
    ref = run_train(60, f"{base}/ref")

    print("2) preempted run: killed after 40 steps (checkpoint at 40)")
    run_train(60, f"{base}/pre", stop_after=40)   # preempted at 40

    print("3) restart with --resume: continues 40 -> 60")
    res = run_train(60, f"{base}/pre", resume=True)

    print(f"   reference final loss: {ref['final_loss']:.6f}")
    print(f"   restarted final loss: {res['final_loss']:.6f}")
    diff = abs(ref["final_loss"] - res["final_loss"])
    print(f"   |diff| = {diff:.2e}  (restart-exact: {diff < 1e-5})")
    assert diff < 1e-5


if __name__ == "__main__":
    main()
