"""End-to-end training driver example — a ~100M-param dense LM trained
for a few hundred steps on the deterministic synthetic stream, with
Guardian fencing active, checkpoints, and restart.

Container-friendly defaults (~10-20 min on 1 CPU core); pass --steps 300
for the full run, or --tiny for a 30-second sanity pass.

    PYTHONPATH=src python examples/train_100m.py --tiny
"""

import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/guardian_100m_ckpt")
    args = ap.parse_args()

    from repro.configs import ModelConfig, register
    from repro.launch import train as T

    # ~100M params: 12 x d768 llama-style decoder, 32k vocab
    register(ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=2048, vocab=32_000, head_dim=64,
        norm="rmsnorm", act="silu", dtype="float32",
        source="examples/train_100m"))

    if args.tiny:
        argv = ["train", "--arch", "demo-100m", "--reduced",
                "--steps", "60", "--batch", "8", "--seq", "128",
                "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "25", "--log-every", "10"]
    else:
        argv = ["train", "--arch", "demo-100m",
                "--steps", str(args.steps), "--batch", "4",
                "--seq", "256", "--lr", "6e-4",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
                "--log-every", "10", "--resume"]
    old = sys.argv
    sys.argv = argv
    try:
        summary = T.main()
    finally:
        sys.argv = old
    print(f"loss {summary['first_loss']:.3f} -> "
          f"{summary['final_loss']:.3f} over {summary['steps']} steps; "
          f"checkpoints in {args.ckpt_dir} (restart with --resume)")


if __name__ == "__main__":
    main()
