"""Serve-path smoke per model family: every registered arch either
serves end-to-end through a reduced ServeEngine or is skipped with the
concrete API gap that makes it unservable.

One parametrized case per ``list_archs()`` entry, so adding an arch to
the registry automatically adds its serve obligation (or forces an
explicit skip entry here).  Each served arch also exercises the request
span ledger: one completed span whose phase components reconcile to its
end-to-end latency.
"""

import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.launch.serve import ServeEngine

#: families the text serve path cannot drive yet, with the exact reason
#: (kept in sync with benchmarks/production_trace.py's fleet choice)
UNSERVABLE = {
    "vlm": ("prefill requires the vision 'patches' input the text-only "
            "serve path does not synthesize"),
    "encdec": ("encoder-decoder cache API lacks the slab engine's "
               "per-slot init (init_cache() has no slots parameter)"),
}


@pytest.mark.parametrize("arch", list_archs())
def test_family_serves_reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.family in UNSERVABLE:
        pytest.skip(f"{arch} ({cfg.family}): {UNSERVABLE[cfg.family]}")
    eng = ServeEngine(cfg, max_batch=2, max_len=16, seed=0)
    eng.register_tenant("t", 2)
    prompt = np.arange(1, 5, dtype=np.int32) % cfg.vocab
    rid = eng.submit("t", prompt)
    out = eng.run(max_new_tokens=2)
    assert len(out[rid]) == 2
    assert all(0 <= t < cfg.vocab for t in out[rid])

    tel = eng.manager.telemetry
    assert tel.spans.totals == {"complete": 1}
    assert tel.spans.open_count() == 0
    sp = tel.spans.closed[-1]
    assert sum(sp.components().values()) == sp.e2e_cycles


def test_unservable_reasons_are_current():
    """The skip table must not go stale: every listed family still
    exists in the registry, and every family is either served by the
    parametrized case above or listed with a reason."""
    families = {get_config(a).family for a in list_archs()}
    assert set(UNSERVABLE) <= families
    assert families - set(UNSERVABLE) >= {"dense", "moe", "ssm", "hybrid"}
