"""Layer-level tests: flash attention vs naive ref across masks, RoPE /
M-RoPE, norms, cross-entropy."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def ref_attn(q, k, v, causal=True, window=0, q_offset=0, kv_len=None):
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask = mask & (kv_pos[None, :] < kv_len)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D)


@pytest.mark.parametrize("window", [0, 13])
@pytest.mark.parametrize("S,qc,kc", [(64, 16, 16), (100, 32, 16),
                                     (128, 128, 128)])
def test_chunked_attention_fwd(window, S, qc, kc):
    rng = jax.random.PRNGKey(S + window)
    ks = jax.random.split(rng, 3)
    B, H, KH, D = 2, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    o1 = L.chunked_attention(q, k, v, causal=True, window=window,
                             q_chunk=qc, kv_chunk=kc)
    o2 = ref_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_chunked_attention_grads():
    rng = jax.random.PRNGKey(7)
    ks = jax.random.split(rng, 3)
    B, S, H, KH, D = 2, 96, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))

    def f1(q, k, v):
        return jnp.sum(jnp.sin(L.chunked_attention(
            q, k, v, causal=True, q_chunk=32, kv_chunk=32)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(ref_attn(q, k, v, causal=True)))

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5)


def test_decode_attention_matches_ref():
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 3)
    B, S, H, KH, D = 3, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    kv_len = jnp.asarray([10, 33, 64], jnp.int32)
    o1 = L.decode_attention(q, k, v, kv_len)
    for b in range(B):
        o2 = ref_attn(q[b:b + 1], k[b:b + 1], v[b:b + 1], causal=False,
                      kv_len=int(kv_len[b]))
        np.testing.assert_allclose(np.asarray(o1[b]), np.asarray(o2[0]),
                                   atol=2e-5)


def test_rope_properties():
    """RoPE preserves norms and is relative: scores depend only on the
    position difference."""
    rng = jax.random.PRNGKey(0)
    B, S, H, D = 1, 8, 1, 32
    x = jax.random.normal(rng, (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), atol=1e-4)
    # relative property: <R(p)q, R(p+d)k> == <R(0)q, R(d)k>
    q = jax.random.normal(rng, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    for p, d in [(0, 3), (5, 3), (11, 3)]:
        qp = L.apply_rope(q, jnp.full((1, 1), p, jnp.int32), 1e4)
        kp = L.apply_rope(k, jnp.full((1, 1), p + d, jnp.int32), 1e4)
        got = float(jnp.sum(qp * kp))
        q0 = L.apply_rope(q, jnp.zeros((1, 1), jnp.int32), 1e4)
        kd = L.apply_rope(k, jnp.full((1, 1), d, jnp.int32), 1e4)
        want = float(jnp.sum(q0 * kd))
        assert abs(got - want) < 1e-4


def test_mrope_equals_rope_when_positions_equal():
    rng = jax.random.PRNGKey(0)
    B, S, H, D = 2, 8, 2, 32
    x = jax.random.normal(rng, (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    pos3 = jnp.repeat(pos[..., None], 3, axis=-1)
    y1 = L.apply_rope(x, pos, 1e4)
    y2 = L.apply_mrope(x, pos3, 1e4, L.mrope_sections(D))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_norms():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 3 + 1
    y = L.rmsnorm(x, jnp.ones(16))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
    z = L.layernorm(x, jnp.ones(16), jnp.zeros(16))
    np.testing.assert_allclose(np.mean(np.asarray(z), -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.std(np.asarray(z), -1), 1.0, atol=1e-2)


def test_cross_entropy_matches_manual():
    rng = jax.random.PRNGKey(0)
    logits = jax.random.normal(rng, (2, 5, 11))
    labels = jax.random.randint(rng, (2, 5), 0, 11)
    got = float(L.softmax_cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = -float(jnp.mean(jnp.take_along_axis(
        p, labels[..., None], axis=-1)))
    assert abs(got - want) < 1e-5
    # masked variant
    mask = jnp.asarray([[1, 1, 0, 0, 0], [1, 0, 0, 0, 0]], jnp.float32)
    got_m = float(L.softmax_cross_entropy(logits, labels, mask))
    rows = -np.asarray(jnp.take_along_axis(p, labels[..., None], -1))[..., 0]
    want_m = (rows * np.asarray(mask)).sum() / 3
    assert abs(got_m - want_m) < 1e-5
