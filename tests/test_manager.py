"""GuardianManager behaviour — memory validation, sandboxed launches,
spatial multiplexing, fault isolation (Guardian §4.2, §5)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FencePolicy,
    GuardianManager,
    GuardianViolation,
    SharingMode,
)
from repro.core.interception import DevicePtr
from repro.core.libsim import GrdBLAS, GrdSPARSE, register_all_libraries


def make_manager(**kw):
    kw.setdefault("total_slots", 256)
    return GuardianManager(**kw)


def test_malloc_within_partition():
    mgr = make_manager()
    c = mgr.register_tenant("a", 64)
    p1 = c.malloc(10)
    part = mgr.bounds.lookup("a")
    assert part.contains(p1.addr, p1.end)


def test_transfer_validation_blocks_cross_tenant():
    mgr = make_manager()
    a = mgr.register_tenant("a", 64)
    b = mgr.register_tenant("b", 64)
    pa = a.malloc(8)
    pb = b.malloc(8)
    a.memcpy_h2d(pa, np.arange(8, dtype=np.float32))
    # tenant a forges a pointer into b's partition
    import dataclasses
    forged = dataclasses.replace(pa, addr=pb.addr)
    with pytest.raises(GuardianViolation):
        a.memcpy_h2d(forged, np.zeros(8, np.float32))
    with pytest.raises(GuardianViolation):
        a.memcpy_d2h(forged, 8)
    assert mgr.violations


def test_sandboxed_kernel_cannot_touch_neighbour():
    """The paper's core guarantee: an adversarial kernel that writes at
    attacker-controlled offsets only corrupts its own partition."""
    mgr = make_manager(policy=FencePolicy.BITWISE)
    a = mgr.register_tenant("a", 64)
    b = mgr.register_tenant("b", 64)
    pb = b.malloc(16)
    b.memcpy_h2d(pb, np.full(16, 7.0, np.float32))
    b.synchronize()

    def evil(arena, target, n):
        idx = target + jnp.arange(n, dtype=jnp.int32)
        return arena.at[idx].set(999.0), None

    a.module_load("evil", evil)
    # attacker aims straight at b's buffer
    a.launch_kernel("evil", args=(jnp.int32(pb.addr), 16))
    a.synchronize()
    out = b.memcpy_d2h(pb, 16)
    np.testing.assert_array_equal(out, np.full(16, 7.0, np.float32))
    # and the damage landed inside a's own partition (wrap-around)
    part_a = mgr.bounds.lookup("a")
    own = np.asarray(mgr.arena.unsafe_read_range(part_a.base, part_a.size))
    assert (own == 999.0).any()


def test_check_policy_detects_oob():
    mgr = make_manager(policy=FencePolicy.CHECK,
                       mode=SharingMode.TIME_SHARE)
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)

    def evil(arena, n):
        idx = 9999 + jnp.arange(n, dtype=jnp.int32)
        return arena.at[idx].set(1.0), None

    # verify=False: the static verifier would refute this constant-OOB
    # scatter at trace time (test_verifier.py covers that); this test
    # pins the *runtime* CHECK containment fallback
    a.module_load("evil2", evil, verify=False)
    with pytest.raises(GuardianViolation):
        a.launch_kernel("evil2", args=(4,))


def test_standalone_fast_path():
    mgr = make_manager(policy=FencePolicy.BITWISE)
    a = mgr.register_tenant("a", 64)
    assert mgr.standalone
    assert mgr._effective_policy() is FencePolicy.NONE
    mgr.register_tenant("b", 64)
    assert not mgr.standalone
    assert mgr._effective_policy() is FencePolicy.BITWISE


def test_modulo_policy_roundtrip():
    mgr = make_manager(policy=FencePolicy.MODULO,
                       mode=SharingMode.TIME_SHARE)
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)
    pa = a.malloc(8)
    a.memcpy_h2d(pa, np.arange(8, dtype=np.float32))

    def double(arena, ptr, n):
        idx = ptr + jnp.arange(n, dtype=jnp.int32)
        vals = jnp.take(arena, idx, axis=0)
        return arena.at[idx].set(2 * vals), None

    a.module_load("double", double)
    a.launch_kernel("double", ptrs=[pa], args=(8,))
    a.synchronize()
    np.testing.assert_allclose(a.memcpy_d2h(pa, 8),
                               2 * np.arange(8, dtype=np.float32))


def test_teardown_scrubs_partition():
    mgr = make_manager()
    a = mgr.register_tenant("a", 64)
    pa = a.malloc(8)
    a.memcpy_h2d(pa, np.full(8, 5.0, np.float32))
    a.synchronize()
    base = mgr.bounds.lookup("a").base
    mgr.remove_tenant("a")
    got = np.asarray(mgr.arena.unsafe_read_range(base, 64))
    assert (got == 0).all()


def test_spatial_round_robin_interleaves():
    mgr = make_manager(mode=SharingMode.SPATIAL)
    a = mgr.register_tenant("a", 64)
    b = mgr.register_tenant("b", 64)

    def noop(arena, n):
        return arena, None

    a.module_load("ka", noop)
    b.module_load("kb", noop)
    for _ in range(3):
        a.launch_kernel("ka", args=(1,))
    for _ in range(3):
        b.launch_kernel("kb", args=(1,))
    order = []
    real = mgr._run_op

    def spy(op):
        order.append(op.tenant_id)
        return real(op)

    mgr._run_op = spy
    mgr.run_queued()
    # round-robin: a,b,a,b,a,b — not a,a,a,b,b,b
    assert order == ["a", "b", "a", "b", "a", "b"]


def test_unknown_kernel_fails_closed():
    mgr = make_manager()
    a = mgr.register_tenant("a", 64)
    with pytest.raises(GuardianViolation):
        a.launch_kernel("not_registered")


def test_libsim_end_to_end_with_implicit_calls():
    """Closed-source-library simulation: implicit runtime calls are traced
    (Table 6) and the double-indirection SpMV is fenced."""
    mgr = make_manager(total_slots=1024, mode=SharingMode.TIME_SHARE)
    register_all_libraries(mgr)
    a = mgr.register_tenant("a", 256)
    blas = GrdBLAS(a).create()
    x = a.malloc(16)
    a.memcpy_h2d(x, np.arange(16, dtype=np.float32) - 8)
    idx = blas.isamax(x, 16)
    assert int(idx) == 0          # |-8| is max
    calls = a.trace.implicit_calls()
    assert "cublasCreate" in calls
    assert calls["cublasCreate"].get("cudaMalloc", 0) == 3
    assert "cublasIsamax" in calls

    # adversarial SpMV: column indices point outside the partition
    mgr2 = make_manager(total_slots=512, mode=SharingMode.TIME_SHARE)
    register_all_libraries(mgr2)
    t1 = mgr2.register_tenant("t1", 128)
    t2 = mgr2.register_tenant("t2", 128)
    victim = t2.malloc(16)
    t2.memcpy_h2d(victim, np.full(16, 3.0, np.float32))
    sp = GrdSPARSE(t1)
    vals = t1.malloc(8)
    cols = t1.malloc(8)
    xv = t1.malloc(8)
    yv = t1.malloc(8)
    t1.memcpy_h2d(vals, np.ones(8, np.float32))
    # poison: absolute addresses into t2's partition
    t1.memcpy_h2d(cols, np.full(8, float(victim.addr), np.float32))
    sp.csr_spmv(vals, cols, xv, yv, nnz=8, n=8)
    t1.synchronize()
    np.testing.assert_array_equal(t2.memcpy_d2h(victim, 16),
                                  np.full(16, 3.0, np.float32))


# ---------------------------------------------------------------------------
# PoolArena edge cases exposed by elastic resizing
# ---------------------------------------------------------------------------


def test_pool_arena_zero_slot_pool_threads_through_steps():
    """A zero-slot pool (a tenant shrunk to nothing / a cold engine) is
    a legal PoolArena: trusted steps thread it through compiled and
    fused dispatch without special-casing."""
    mgr = make_manager(total_slots=64)
    empty = {"k": jnp.zeros((2, 0, 4), jnp.float32)}
    mgr.register_pool("empty_pool", empty)

    def step(arena, pool, x):
        return arena, pool, x + pool["k"].shape[1]   # slot count = 0

    mgr.register_trusted_kernel("step0", step, pool_arena="empty_pool")
    a = mgr.register_tenant("a", 8)
    b = mgr.register_tenant("b", 8)
    ra = a.launch_kernel("step0", args=(jnp.float32(1.0),))
    rb = b.launch_kernel("step0", args=(jnp.float32(2.0),))
    mgr.synchronize()
    assert float(ra.result) == 1.0 and float(rb.result) == 2.0
    assert mgr.scheduler.stats.fused_steps == 1   # zero slots still fuse
    assert mgr.arenas["empty_pool"].buf["k"].shape == (2, 0, 4)


def test_pool_slot_map_rewrite_defers_under_queued_decodes():
    """The elastic manager must never rewrite a pool slot map while
    decode steps are queued against it (their staged operands reference
    the old extent): relocation is refused until the drain, then lands
    with the moved slots intact."""
    from repro.core import ElasticError

    mgr = make_manager(total_slots=64)
    pool = {"k": jnp.zeros((2, 64, 4), jnp.float32)}
    mgr.register_pool("kv", pool)

    def decode(arena, pool, slot):
        k = pool["k"].at[:, slot].add(1.0)
        return arena, {"k": k}, None

    mgr.register_trusted_kernel("decode", decode, pool_arena="kv")
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    part = mgr.bounds.lookup("a")
    slot = jnp.int32(part.base)
    for _ in range(3):
        a.launch_kernel("decode", args=(slot,))
    # queued decodes: the slot-map rewrite must wait
    with pytest.raises(ElasticError):
        mgr.elastic.relocate("a", 16)
    mgr.synchronize()
    new = mgr.elastic.relocate("a", 16)           # drained: legal now
    assert new.base != part.base
    # pool rows moved with the extent is the *serve engine's* listener
    # job; at manager level the decode results landed pre-move
    assert float(mgr.arenas["kv"].buf["k"][0, part.base, 0]) == 3.0


def test_trusted_donation_declared_but_noop_on_cpu():
    """donate_argnums on a trusted kernel is compiled in but inert on
    CPU (donation_supported() is False): the donated operand's buffer
    survives the call — the documented no-op — and results match."""
    from repro.core.scheduler import donation_supported

    assert not donation_supported()               # CPU test environment
    mgr = make_manager(total_slots=64)

    def step(arena, consumed, x):
        return arena, consumed * 0 + x

    mgr.register_trusted_kernel("dstep", step, donate_argnums=(1,))
    c = mgr.register_tenant("svc", 16)
    buf = jnp.full((8,), 3.0, jnp.float32)
    req = c.launch_kernel("dstep", args=(buf, jnp.float32(5.0)))
    mgr.synchronize()
    np.testing.assert_array_equal(np.asarray(req.result),
                                  np.full(8, 5.0, np.float32))
    # no donation happened: the operand is still alive and readable
    np.testing.assert_array_equal(np.asarray(buf),
                                  np.full(8, 3.0, np.float32))
    # and the compiled entry cached under the trusted key
    entry = mgr.pointer_to_symbol["dstep"]
    assert any(k[0] == "trusted" for k in entry.jit_cache)
