"""Continuous batching over the fenced paged KV pool (serve path).

Proof obligations for the per-request driver:

* **bit-identity** — continuous generations equal lockstep / solo-run
  generations token for token (same-arrival and churny arrival traces);
* **containment** — forged virtual page tables wrap into the forger's
  own extent; join/leave churn never aliases a live page;
* **zero-copy elasticity** — grows, rebases and background compaction
  never dispatch a data-moving relocation step in paged mode;
* **sampling** — temperature/top-k decode is deterministic per PRNG key
  and the greedy default compiles the unchanged argmax program.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.elastic import ElasticPolicy
from repro.core.fence import FenceParams, FencePolicy
from repro.launch.serve import (
    ServeEngine,
    make_shared_manager,
    serve_continuous,
    serve_engines,
)
from repro.models import kvcache as KV
from repro.models.guard import GuardSpec


CFG = get_config("stablelm-3b").reduced()


def _prompts(n, plen=6, salt=0):
    return [[(7 * i + 3 * j + salt) % 211 + 1 for j in range(plen)]
            for i in range(n)]


def _solo_refs(prompts, budgets, max_len=64):
    refs = []
    for p, b in zip(prompts, budgets):
        eng = ServeEngine(CFG, max_batch=2, max_len=max_len, seed=0)
        eng.register_tenant("solo", 2)
        rid = eng.submit("solo", p)
        refs.append(eng.run(max_new_tokens=b)[rid])
    return refs


# --------------------------------------------------------------------- #
# Bit-identity                                                          #
# --------------------------------------------------------------------- #
def test_continuous_matches_lockstep_same_arrival():
    """Same-arrival uniform workload: the continuous driver and the
    lockstep slab driver emit identical tokens per request."""
    prompts = _prompts(4)
    budget = 5

    lock = ServeEngine(CFG, max_batch=4, max_len=64, seed=0)
    lock.register_tenant("t", 4)
    lock_rids = [lock.submit("t", p) for p in prompts]
    lock_out = serve_engines([lock], max_new_tokens=budget)[0]

    mgr = make_shared_manager(1, max_batch=4, paged=True, max_len=64)
    cont = ServeEngine(CFG, max_batch=4, max_len=64, seed=0,
                       manager=mgr, paged=True)
    cont.register_tenant("t", 4)
    cont_rids = [cont.submit("t", p, max_new=budget) for p in prompts]
    cont_out = serve_continuous([cont], max_new_tokens=budget)[0]

    for lr, cr in zip(lock_rids, cont_rids):
        assert cont_out[cr] == lock_out[lr]
    assert cont.manager.elastic.stats["reloc_steps"] == 0


def test_continuous_churn_solo_identity():
    """Staggered arrivals, mixed budgets, two tenants (one sized to
    force an elastic grow): every request's generation equals its solo
    run — rows joining/leaving mid-flight never perturb neighbours."""
    n = 8
    prompts = _prompts(n)
    budgets = [3 if i % 2 else 6 for i in range(n)]
    refs = _solo_refs(prompts, budgets)

    mgr = make_shared_manager(1, max_batch=4, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=4, max_len=64, seed=0,
                      manager=mgr, paged=True, max_inflight=4)
    eng.register_tenant("a", 4)
    eng.register_tenant("b", 1)      # 1-page extent: churn forces reuse
    rids = [eng.submit("b" if i % 3 == 0 else "a", prompts[i],
                       max_new=budgets[i], arrive=i // 2)
            for i in range(n)]
    out = serve_continuous([eng], max_new_tokens=16)[0]

    for i, rid in enumerate(rids):
        assert out[rid] == refs[i], f"request {i} diverged"
    assert eng.manager.elastic.stats["reloc_steps"] == 0


def test_short_request_row_refills_immediately():
    """A finished short request's row refills from the admission queue at
    the next cycle boundary: total cycles stay well under the sum of
    sequential waves."""
    prompts = _prompts(6)
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 2)
    for i, p in enumerate(prompts):
        eng.submit("t", p, max_new=2 if i else 8)
    st = eng._cont_begin(16)
    # drive manually to observe the state
    while True:
        eng._cont_leave(st)
        joiners = eng._cont_join(st)
        h = eng._cont_dispatch(st, joiners)
        if h[0] is None and h[1] is None and not eng._cont_waiting(st):
            break
        eng.manager.run_queued()
        eng._cont_finish(st, *h)
    out = eng._cont_finalize(st)
    assert len(out) == 6
    # 1 long (8 cycles incl. prefill) + 5 shorts (2 cycles each) on 2
    # rows: continuous packs the shorts into the long request's shadow;
    # lockstep waves would cost ~3 waves x wave-max cycles
    assert st.cycles <= 13


# --------------------------------------------------------------------- #
# Containment                                                           #
# --------------------------------------------------------------------- #
def test_forged_virtual_page_table_stays_fenced():
    """Serve-path containment: a page table forged with another tenant's
    virtual ids wraps into the forger's own extent before the page_map
    translation — the victim's physical pages are never read."""
    cache = KV.init_global_kv_cache(CFG, 2, 128, 16)
    pages_per_req = cache.page_table.shape[1]
    assert pages_per_req == 2

    # virtual space: attacker owns [0, 2) -> phys [1, 2];
    #                victim   owns [4, 6) -> phys [3, 4]
    page_map = np.zeros((8,), np.int32)
    page_map[0:2] = [1, 2]
    page_map[4:6] = [3, 4]

    def guard_for(tables_rows):
        return GuardSpec(
            policy=FencePolicy.BITWISE,
            kv=FenceParams(base=jnp.asarray([0, 4], jnp.int32),
                           size=jnp.asarray([2, 2], jnp.int32)),
            page=FenceParams(base=0, size=16),
            page_map=jnp.asarray(page_map))

    rng = np.random.default_rng(0)
    k_new = jnp.asarray(rng.normal(size=(2, 1, CFG.n_kv_heads,
                                         CFG.head_dim)), jnp.float32)
    honest = dataclasses.replace(
        cache, page_table=jnp.asarray([[0, 1], [4, 5]], jnp.int32),
        seq_lens=jnp.asarray([3, 3], jnp.int32))
    forged = dataclasses.replace(
        honest, page_table=jnp.asarray([[4, 5], [4, 5]], jnp.int32))

    g = guard_for(None)
    c_h = KV.append_token_kv(honest, 0, k_new, k_new, guard=g)
    c_f = KV.append_token_kv(forged, 0, k_new, k_new, guard=g)
    # row 0's forged victim ids wrap to its own extent: victim phys
    # pages (3, 4) hold identical bytes in both runs (only row 1, their
    # real owner, wrote them)
    np.testing.assert_array_equal(np.asarray(c_h.k[:, 3:5]),
                                  np.asarray(c_f.k[:, 3:5]))
    # and the forged write landed somewhere inside attacker phys [1, 2]
    assert (np.asarray(c_f.k[:, 1:3]) != 0).any()

    # reads: gather with forged tables returns attacker-extent bytes,
    # so zeroing the victim's pages changes nothing for row 0
    reads1 = KV.gather_layer_kv(c_f, 0, guard=g)[0]
    c_z = dataclasses.replace(
        c_f, k=c_f.k.at[:, 3:5].set(0.0), v=c_f.v.at[:, 3:5].set(0.0))
    reads2 = KV.gather_layer_kv(c_z, 0, guard=g)[0]
    np.testing.assert_array_equal(np.asarray(reads1[0]),
                                  np.asarray(reads2[0]))


def test_join_leave_never_aliases_freed_page():
    """The join-time allocator invariant holds across heavy churn: pages
    of concurrently active requests are disjoint and inside their
    owner's extent (the assertions inside _cont_join fire otherwise),
    and every request still completes."""
    n = 10
    prompts = _prompts(n, salt=3)
    mgr = make_shared_manager(1, max_batch=4, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=4, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 2)      # 2 pages for 4 rows: constant churn
    rids = [eng.submit("t", p, max_new=1 + i % 3, arrive=i // 3)
            for i, p in enumerate(prompts)]
    out = serve_continuous([eng], max_new_tokens=8)[0]
    assert sorted(out) == sorted(rids)
    for i, rid in enumerate(rids):
        assert len(out[rid]) == 1 + i % 3


# --------------------------------------------------------------------- #
# Zero-copy elasticity                                                  #
# --------------------------------------------------------------------- #
def test_background_compaction_is_zero_copy():
    """Evicting a middle tenant fragments the virtual space; idle drain
    cycles trigger the PressureTracker-driven background compaction,
    which rebases extents through the PagePool map — zero relocation
    steps — and post-compaction generations stay bit-identical."""
    prompts = _prompts(2)
    refs = _solo_refs(prompts, [4, 4])

    mgr = make_shared_manager(2, max_batch=4, paged=True, max_len=64,
                              elastic_policy=ElasticPolicy(
                                  background_compact=True,
                                  compact_interval=2))
    eng = ServeEngine(CFG, max_batch=4, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("a", 4)
    eng.register_tenant("b", 4)
    eng.register_tenant("c", 4)

    rid0 = eng.submit("c", prompts[0], max_new=4)
    out = serve_continuous([eng], max_new_tokens=4)[0]
    assert out[rid0] == refs[0]

    eng.quarantine_tenant("b")
    eng.evict_tenant("b")            # hole below c
    base_before = mgr.bounds.lookup("c").base
    for _ in range(4):               # idle cycles drive the compactor
        mgr.run_queued()
    assert mgr.elastic.stats["compactions"] >= 1
    assert mgr.bounds.lookup("c").base < base_before
    assert mgr.elastic.stats["reloc_steps"] == 0

    rid1 = eng.submit("c", prompts[1], max_new=4)
    out = serve_continuous([eng], max_new_tokens=4)[0]
    assert out[rid1] == refs[1]
    assert mgr.elastic.stats["reloc_steps"] == 0


# --------------------------------------------------------------------- #
# Sampling                                                              #
# --------------------------------------------------------------------- #
def _sampled_run(seed, temperature=0.7, top_k=4):
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=seed,
                      manager=mgr, paged=True,
                      temperature=temperature, top_k=top_k)
    eng.register_tenant("t", 2)
    rid = eng.submit("t", _prompts(1)[0], max_new=6)
    return eng, eng.run(max_new_tokens=6)[rid]


def test_sampled_decode_deterministic_per_key():
    eng1, toks1 = _sampled_run(0)
    eng2, toks2 = _sampled_run(0)
    assert toks1 == toks2            # same PRNG key -> same stream
    assert "sampled" in eng1._steps.decode_name
    _, toks3 = _sampled_run(1)       # model params differ too, but the
    assert len(toks3) == 6           # run must still complete


def test_greedy_default_pinned():
    """temperature=0 compiles the unchanged argmax decode program under
    the unsuffixed step name — bit-identical to the slab engine's."""
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    assert "sampled" not in eng._steps.decode_name
    assert eng._sample_key is None
    eng.register_tenant("t", 2)
    rid = eng.submit("t", _prompts(1)[0], max_new=5)
    out = eng.run(max_new_tokens=5)
    assert out[rid] == _solo_refs(_prompts(1), [5])[0]
