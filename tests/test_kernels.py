"""Pallas kernel sweeps — every kernel vs its pure-jnp oracle, across
shapes and dtypes, in interpret mode (the assignment's kernel contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops
from repro.kernels import ref as R


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KH,D,page,P_total,max_pages",
    [
        (2, 4, 4, 16, 8, 32, 4),     # MHA
        (4, 8, 2, 32, 16, 64, 8),    # GQA
        (1, 8, 1, 64, 8, 16, 2),     # MQA
        (3, 6, 2, 16, 4, 32, 16),    # odd batch, many pages
    ])
def test_paged_attention_sweep(dtype, B, H, KH, D, page, P_total,
                               max_pages):
    rng = np.random.default_rng(B * 100 + H)
    q = jnp.asarray(rng.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(rng.normal(size=(P_total, page, KH, D)), dtype)
    vp = jnp.asarray(rng.normal(size=(P_total, page, KH, D)), dtype)
    half = P_total // 2
    base = jnp.asarray(rng.choice([0, half], size=B), jnp.int32)
    mask = jnp.full((B,), half - 1, jnp.int32)
    pt = jnp.asarray(rng.integers(0, P_total, size=(B, max_pages)),
                     jnp.int32)
    lens = jnp.asarray(rng.integers(1, max_pages * page, size=B),
                       jnp.int32)
    out = ops.paged_attention(q, kp, vp, pt, lens, base, mask)
    ref = R.paged_attention_ref(q, kp, vp, pt, lens, base, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=2, max_value=6))
@settings(max_examples=10, deadline=None)
def test_paged_attention_isolation_property(seed, logsize):
    """Adversarial page tables never read outside the tenant partition:
    outputs must be identical whether the other tenant's pool half is
    zeroed or randomized."""
    rng = np.random.default_rng(seed)
    P_total = 2 ** logsize
    half = P_total // 2
    B, H, KH, D, page, max_pages = 2, 4, 2, 16, 4, 4
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    vp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    pt = jnp.asarray(rng.integers(0, P_total, size=(B, max_pages)),
                     jnp.int32)   # ids point everywhere
    lens = jnp.full((B,), max_pages * page, jnp.int32)
    base = jnp.zeros((B,), jnp.int32)       # tenant owns [0, half)
    mask = jnp.full((B,), half - 1, jnp.int32)
    out1 = ops.paged_attention(q, jnp.asarray(kp), jnp.asarray(vp), pt,
                               lens, base, mask)
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[half:] = 12345.0      # mutate the OTHER tenant's half
    vp2[half:] = -999.0
    out2 = ops.paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), pt,
                               lens, base, mask)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,N", [(32, 8, 4), (128, 64, 32),
                                   (1024, 128, 7)])
def test_fenced_gather_sweep(dtype, V, D, N):
    rng = np.random.default_rng(V + N)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    idx = jnp.asarray(rng.integers(-V, 2 * V, size=(N,)), jnp.int32)
    base, mask = V // 2, V // 2 - 1
    out = ops.gather_rows(table, idx, base, mask)
    ref = R.gather_rows_ref(table, idx, base, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,page,KH,D,N", [(16, 4, 2, 8, 3),
                                           (64, 16, 4, 32, 8)])
def test_fenced_scatter_sweep(dtype, P, page, KH, D, N):
    rng = np.random.default_rng(P + N)
    pool = jnp.zeros((P, page, KH, D), dtype)
    pages = jnp.asarray(rng.normal(size=(N, page, KH, D)), dtype)
    ids = jnp.asarray(rng.integers(0, 4 * P, size=(N,)), jnp.int32)
    base, mask = 0, P // 2 - 1
    out = ops.scatter_pages(pool, pages, ids, base, mask)
    ref = R.scatter_pages_ref(jnp.zeros((P, page, KH, D), dtype), pages,
                              ids, base, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # isolation: nothing written at or beyond P//2
    assert (np.asarray(out)[P // 2:] == 0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,D,qb,kb", [
    (1, 128, 4, 4, 32, 128, 128),
    (2, 256, 4, 2, 32, 128, 128),
    (2, 256, 8, 1, 64, 64, 128),
])
def test_flash_attention_sweep(dtype, B, S, H, KH, D, qb, kb):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    out = ops.flash_attention(q, k, v, causal=True, q_blk=qb, kv_blk=kb)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(min_value=1, max_value=300),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=2, max_value=5))
@settings(max_examples=15, deadline=None)
def test_moe_histogram_property(T, K, loge):
    E = 2 ** loge
    rng = np.random.default_rng(T * K)
    ids = jnp.asarray(rng.integers(0, 2 * E, size=(T, K)), jnp.int32)
    out = ops.moe_histogram(ids, E, 0, E // 2 - 1 if E > 1 else 0)
    ref = R.moe_histogram_ref(ids, E, 0, E // 2 - 1 if E > 1 else 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(out.sum()) == T * K


# ---------------------------------------------------------------------------
# Deterministic seeded mirrors of the hypothesis properties (always run).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,logsize", [(1, 2), (7, 3), (13, 4), (42, 6)])
def test_paged_attention_isolation_sweep(seed, logsize):
    """Mirror of the isolation property: mutating the other tenant's pool
    half never changes the fenced outputs."""
    rng = np.random.default_rng(seed)
    P_total = 2 ** logsize
    half = P_total // 2
    B, H, KH, D, page, max_pages = 2, 4, 2, 16, 4, 4
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    vp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    pt = jnp.asarray(rng.integers(0, P_total, size=(B, max_pages)),
                     jnp.int32)
    lens = jnp.full((B,), max_pages * page, jnp.int32)
    base = jnp.zeros((B,), jnp.int32)
    mask = jnp.full((B,), half - 1, jnp.int32)
    out1 = ops.paged_attention(q, jnp.asarray(kp), jnp.asarray(vp), pt,
                               lens, base, mask)
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[half:] = 12345.0
    vp2[half:] = -999.0
    out2 = ops.paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), pt,
                               lens, base, mask)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KH,D,page,P_total,max_pages",
    [
        (2, 4, 4, 16, 8, 32, 4),
        (4, 8, 2, 32, 16, 64, 8),
        (3, 6, 2, 16, 4, 32, 16),
    ])
def test_paged_attention_page_map_sweep(dtype, B, H, KH, D, page, P_total,
                                        max_pages):
    """Serve-path layout: page tables hold VIRTUAL ids translated through
    a manager-owned page_map after the fence.  Kernel vs oracle."""
    rng = np.random.default_rng(B * 100 + H + 1)
    n_virt = 2 * P_total
    q = jnp.asarray(rng.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(rng.normal(size=(P_total, page, KH, D)), dtype)
    vp = jnp.asarray(rng.normal(size=(P_total, page, KH, D)), dtype)
    half = n_virt // 2
    base = jnp.asarray(rng.choice([0, half], size=B), jnp.int32)
    mask = jnp.full((B,), half - 1, jnp.int32)
    pmap = jnp.asarray(rng.permutation(P_total)[
        rng.integers(0, P_total, size=n_virt)], jnp.int32)
    pt = jnp.asarray(rng.integers(0, n_virt, size=(B, max_pages)),
                     jnp.int32)
    lens = jnp.asarray(rng.integers(1, max_pages * page, size=B),
                       jnp.int32)
    out = ops.paged_attention(q, kp, vp, pt, lens, base, mask, pmap)
    ref = R.paged_attention_ref(q, kp, vp, pt, lens, base, mask, pmap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("seed,logsize", [(3, 3), (11, 4), (29, 5)])
def test_paged_attention_page_map_isolation(seed, logsize):
    """Serve-path isolation proof: with virtual extents + page_map
    translation, an adversarial page table full of other-tenant virtual
    ids still only reaches the physical pages the map assigns to the
    attacker's own extent — mutating every other physical page changes
    nothing."""
    rng = np.random.default_rng(seed)
    P_total = 2 ** logsize
    n_virt = P_total
    half = n_virt // 2
    B, H, KH, D, page, max_pages = 2, 4, 2, 16, 4, 4
    # tenant A owns virtual [0, half) mapped to ODD physical pages; the
    # rest of the pool (even pages + page 0) belongs to others
    pmap = np.zeros((n_virt,), np.int32)
    a_phys = [p for p in range(1, P_total) if p % 2 == 1][:half]
    for v, p in enumerate(a_phys):
        pmap[v] = p
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    vp = np.asarray(rng.normal(size=(P_total, page, KH, D)), np.float32)
    # adversarial: virtual ids spray the whole virtual space
    pt = jnp.asarray(rng.integers(0, n_virt, size=(B, max_pages)),
                     jnp.int32)
    lens = jnp.full((B,), max_pages * page, jnp.int32)
    base = jnp.zeros((B,), jnp.int32)        # fenced into [0, half)
    mask = jnp.full((B,), half - 1, jnp.int32)
    pmapj = jnp.asarray(pmap)
    out1 = ops.paged_attention(q, jnp.asarray(kp), jnp.asarray(vp), pt,
                               lens, base, mask, pmapj)
    kp2, vp2 = kp.copy(), vp.copy()
    others = [p for p in range(P_total) if p not in set(a_phys)]
    kp2[others] = 31337.0                    # clobber every foreign page
    vp2[others] = -31337.0
    out2 = ops.paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), pt,
                               lens, base, mask, pmapj)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("T,K,E", [(1, 1, 4), (17, 2, 8), (300, 8, 32),
                                   (64, 4, 16)])
def test_moe_histogram_sweep(T, K, E):
    rng = np.random.default_rng(T * K + E)
    ids = jnp.asarray(rng.integers(0, 2 * E, size=(T, K)), jnp.int32)
    out = ops.moe_histogram(ids, E, 0, E // 2 - 1 if E > 1 else 0)
    ref = R.moe_histogram_ref(ids, E, 0, E // 2 - 1 if E > 1 else 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(out.sum()) == T * K
