"""Every fenced ``python`` snippet in the operator documentation must
actually run — docs that drift from the API fail the tier-1 suite, not
an operator's terminal.

Blocks execute in order within one shared namespace per document, so a
later snippet may build on an earlier one (the README's dashboard
snippet reuses its quickstart manager), mirroring a reader pasting them
into one session.  ``bash`` blocks are not executed here; the quickstart
commands are covered by the CI smoke jobs.
"""

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/operator-guide.md"]

_FENCE = re.compile(r"^```python\n(.*?)^```", re.M | re.S)


def _blocks(doc):
    text = (REPO / doc).read_text()
    return _FENCE.findall(text)


@pytest.mark.parametrize("doc", DOCS)
def test_doc_has_python_examples(doc):
    assert _blocks(doc), f"{doc} lost its executable examples"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_python_snippets_execute(doc, capsys):
    ns = {}
    for i, block in enumerate(_blocks(doc)):
        code = compile(block, f"{doc}[python block {i}]", "exec")
        exec(code, ns)
    capsys.readouterr()          # swallow example prints
