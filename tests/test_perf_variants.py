"""Equivalence tests for the §Perf optimization variants — the optimized
paths must be bit-compatible (to float tolerance) with the baselines they
replaced."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.models import kvcache as KV
from repro.models import moe as MOE

RNG = jax.random.PRNGKey(0)


def test_moe_dispatch_scatter_matches_einsum():
    """H1: scatter dispatch == one-hot einsum dispatch (fwd + grads)."""
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    params = MOE.init(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab)
    l1, a1 = MOE.forward(cfg, params, toks[:, :-1], dispatch="einsum")
    l2, a2 = MOE.forward(cfg, params, toks[:, :-1], dispatch="scatter")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-5)
    assert abs(float(a1) - float(a2)) < 1e-6

    g1 = jax.grad(lambda p: MOE.loss_fn(cfg, p, {"tokens": toks},
                                        remat=False,
                                        dispatch="einsum"))(params)
    g2 = jax.grad(lambda p: MOE.loss_fn(cfg, p, {"tokens": toks},
                                        remat=False,
                                        dispatch="scatter"))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


def test_prefill_write_permute_matches_scatter():
    """H2: permute-formulated prefill writes == direct scatter writes,
    including non-identity (but honest) page tables and slot ids."""
    cfg = get_config("llama3-405b").reduced()
    B, S = 3, 40
    cache = KV.init_kv_cache(cfg, B, 128, dtype=jnp.float32, slots=8)
    rng = np.random.default_rng(0)
    # honest permutations: distinct slots, per-slab page permutations
    slot_ids = jnp.asarray([5, 1, 2], jnp.int32)
    P = cache.page_table.shape[1]
    pt = jnp.asarray(np.stack([rng.permutation(P) for _ in range(B)]),
                     jnp.int32)
    import dataclasses
    cache = dataclasses.replace(cache, slot_ids=slot_ids, page_table=pt)
    KH, D = cfg.n_kv_heads, cfg.head_dim
    k_new = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    lidx = jnp.int32(1)
    c1 = KV.write_prefill_kv(cache, lidx, k_new, v_new, mode="scatter")
    c2 = KV.write_prefill_kv(cache, lidx, k_new, v_new, mode="permute")
    np.testing.assert_array_equal(np.asarray(c1.k), np.asarray(c2.k))
    np.testing.assert_array_equal(np.asarray(c1.v), np.asarray(c2.v))


def test_prefill_write_permute_isolation_under_adversarial_tables():
    """H2 safety: even with duplicate/forged page ids, the permute write
    only touches the fenced slot's slab."""
    from repro.core.fence import FenceParams, FencePolicy
    from repro.models.guard import GuardSpec
    cfg = get_config("llama3-405b").reduced()
    B = 2
    cache = KV.init_kv_cache(cfg, B, 128, dtype=jnp.float32, slots=8)
    import dataclasses
    # attacker rows claim slots 6,7 but guard fences into [0,2)
    cache = dataclasses.replace(
        cache, slot_ids=jnp.asarray([6, 7], jnp.int32),
        page_table=jnp.zeros_like(cache.page_table))  # duplicate ids!
    guard = GuardSpec(policy=FencePolicy.BITWISE,
                      kv=FenceParams(base=0, size=2),
                      page=FenceParams(base=0,
                                       size=cache.pages_per_slot))
    rng = np.random.default_rng(1)
    k_new = jnp.asarray(rng.normal(
        size=(B, 40, cfg.n_kv_heads, cfg.head_dim)), jnp.float32)
    c2 = KV.write_prefill_kv(cache, jnp.int32(0), k_new, k_new, guard,
                             mode="permute")
    assert (np.asarray(c2.k[:, 2:]) == 0).all()   # slots >=2 untouched


def test_fp8_kv_cache_decode_runs():
    """H3: fp8 pool decodes without NaNs and stays close to f32."""
    cfg = get_config("stablelm-3b").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    toks = jax.random.randint(RNG, (2, 32), 0, cfg.vocab)
    outs = {}
    for name, dt in (("f32", jnp.float32),
                     ("f8", jnp.float8_e4m3fn)):
        cache = api.init_cache(2, 64, dtype=dt)
        cache, lg = api.prefill(params, cache, {"tokens": toks})
        cache, lg = api.decode(params, cache,
                               jnp.argmax(lg, -1).astype(jnp.int32))
        assert not bool(jnp.any(jnp.isnan(lg)))
        outs[name] = np.asarray(lg, np.float32)
    # fp8 KV quantization error is bounded (same argmax most of the time;
    # here just require finite, correlated outputs)
    corr = np.corrcoef(outs["f32"].ravel(), outs["f8"].ravel())[0, 1]
    assert corr > 0.98
