"""Request-span lifecycle and the SLO attainment ledger (core/telemetry
RequestSpan/SpanLedger + the serve drivers' phase ticks).

Proof obligations:

* **reconciliation** — for every finished span, the per-phase cycle
  components sum *exactly* to the end-to-end latency (the invariant the
  production macro-bench re-asserts at scale);
* **terminal states** — every opened span ends in exactly one of
  complete | evicted | withdrawn; no span leaks (open_count drains to
  zero) through completion, quarantine mid-run, eviction, or withdrawal;
* **attribution** — the wait phases (queue / hold / preempt / stall)
  land on the requests the scheduler actually made wait, for the reason
  it made them wait;
* **off-mode byte-identity** — with telemetry off the ledger records
  nothing, allocates nothing, and the generated tokens are identical;
* **export** — closed spans emit per-request Perfetto tracks linked by
  flow events; ring overflow is counted, reported, and rendered.
"""

import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import TenantClassPolicy
from repro.core.telemetry import (
    EventTrace,
    SLACK_PHASES,
    SPAN_PHASES,
    Telemetry,
)
from repro.launch.dashboard import format_report
from repro.launch.serve import (
    ServeEngine,
    make_shared_manager,
    serve_continuous,
    serve_engines,
)

CFG = get_config("stablelm-3b").reduced()


def _prompts(n, plen=6, salt=0):
    return [[(7 * i + 3 * j + salt) % 211 + 1 for j in range(plen)]
            for i in range(n)]


def _assert_reconciled(tel):
    """Every closed span is terminal and its components sum to e2e."""
    assert tel.spans.open_count() == 0
    assert len(tel.spans.closed) > 0
    for sp in tel.spans.closed:
        assert sp.outcome in ("complete", "evicted", "withdrawn")
        comps = sp.components()
        assert set(comps) == set(SPAN_PHASES)
        assert sum(comps.values()) == sp.e2e_cycles, sp.to_dict()


# --------------------------------------------------------------------- #
# Reconciliation on the real drivers                                    #
# --------------------------------------------------------------------- #
def test_continuous_spans_reconcile_and_complete():
    """Staggered continuous workload: one span per request, all
    complete, components sum exactly to e2e, ledger totals match."""
    n = 6
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 2)
    rids = [eng.submit("t", p, max_new=1 + i % 3, arrive=i // 2)
            for i, p in enumerate(_prompts(n))]
    out = serve_continuous([eng], max_new_tokens=8)[0]
    assert sorted(out) == sorted(rids)

    tel = mgr.telemetry
    _assert_reconciled(tel)
    assert tel.spans.totals == {"complete": n}
    assert len(tel.spans.closed) == n
    # deferred (future-arrival) spans started at eligibility, so no
    # span charges queue time the trace replay itself asked for
    for sp in tel.spans.closed:
        assert sp.started and sp.e2e_cycles >= 1


def test_lockstep_spans_reconcile_and_complete():
    """The slab lockstep driver ticks the same span API: overflow
    requests ride hold/queue across waves and still reconcile."""
    mgr = make_shared_manager(2, max_batch=2)
    eng = ServeEngine(CFG, max_batch=2, max_len=16, seed=0, manager=mgr)
    eng.register_tenant("t", 4)
    rids = [eng.submit("t", p) for p in _prompts(3)]
    out: dict = {}
    for _ in range(3):                   # one serve_engines call per wave
        out.update(serve_engines([eng], max_new_tokens=3)[0])
        if len(out) == len(rids):
            break
    assert sorted(out) == sorted(rids)

    tel = eng.manager.telemetry
    _assert_reconciled(tel)
    assert tel.spans.totals == {"complete": 3}
    # 3 requests on 2 rows: the wave-2 request waited at least one cycle
    waited = [sp for sp in tel.spans.closed if sp.slack_cycles() > 0]
    assert waited, "the overflow request recorded no wait"


# --------------------------------------------------------------------- #
# Wait attribution                                                      #
# --------------------------------------------------------------------- #
def test_preempt_phase_when_bypassed_by_latency_critical():
    """A best-effort request bypassed by a later latency-critical
    arrival charges the wait to ``preempt``, and the ledger books the
    LC tenant's completion against its class."""
    mgr = make_shared_manager(2, max_batch=1, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=1, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("be", 1)
    eng.register_tenant("lc", 1,
                        tenant_class=TenantClassPolicy.latency_critical(
                            queue_age_budget=16))
    p = _prompts(2)
    rid_be = eng.submit("be", p[0], max_new=2)
    rid_lc = eng.submit("lc", p[1], max_new=2)
    out = serve_continuous([eng], max_new_tokens=4)[0]
    assert len(out[rid_be]) == 2 and len(out[rid_lc]) == 2

    tel = mgr.telemetry
    _assert_reconciled(tel)
    by_rid = {sp.rid: sp for sp in tel.spans.closed}
    # LC joined first despite submitting second; the bypassed BE
    # request's wait is attributed to preempt
    assert by_rid[rid_lc].components()["preempt"] == 0
    assert by_rid[rid_be].components()["preempt"] > 0
    ledger = tel.spans.to_dict()
    assert ledger["classes"]["latency_critical"]["attained"] == 1
    assert tel.registry.counter("slo_attained", tenant="lc") == 1


def test_stall_phase_when_page_pool_full():
    """A request blocked on its tenant's paged-KV extent (not on batch
    capacity) charges the wait to ``stall``."""
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 1)          # one page: second request stalls
    eng.register_tenant("f", 1)          # filler: the stall-time elastic
    p = _prompts(2)                      # grow finds no free block
    rid0 = eng.submit("t", p[0], max_new=3)
    rid1 = eng.submit("t", p[1], max_new=1)
    out = serve_continuous([eng], max_new_tokens=4)[0]
    assert sorted(out) == sorted([rid0, rid1])

    tel = mgr.telemetry
    _assert_reconciled(tel)
    by_rid = {sp.rid: sp for sp in tel.spans.closed}
    assert by_rid[rid1].components()["stall"] > 0


def test_hold_phase_and_violation_cause():
    """Batch-capacity waits charge ``hold``; a latency-critical span
    that completes over budget is a violation whose cause is the
    dominant slack phase."""
    mgr = make_shared_manager(2, max_batch=1, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=1, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("lc", 2,
                        tenant_class=TenantClassPolicy.latency_critical(
                            queue_age_budget=0))
    p = _prompts(2)
    eng.submit("lc", p[0], max_new=3)
    rid1 = eng.submit("lc", p[1], max_new=1)
    serve_continuous([eng], max_new_tokens=4)

    tel = mgr.telemetry
    _assert_reconciled(tel)
    by_rid = {sp.rid: sp for sp in tel.spans.closed}
    assert by_rid[rid1].components()["hold"] > 0
    row = tel.spans.to_dict()["classes"]["latency_critical"]
    # first request fit the zero budget; the held one violated it
    assert row == {"attained": 1, "violated": 1,
                   "attainment": 0.5, "causes": {"hold": 1}}


# --------------------------------------------------------------------- #
# Terminal-state edge cases                                             #
# --------------------------------------------------------------------- #
def test_withdrawn_request_closes_span():
    """Withdrawing a queued (never-ran, deferred-clock) request closes
    its span zero-length as ``withdrawn``; running/done requests refuse
    withdrawal."""
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 2)
    p = _prompts(2)
    rid0 = eng.submit("t", p[0], max_new=2)
    rid1 = eng.submit("t", p[1], max_new=2, arrive=50)
    assert eng.withdraw(rid1) is True
    assert eng.withdraw(rid1) is False          # already gone
    out = serve_continuous([eng], max_new_tokens=4)[0]
    assert rid1 not in out
    assert eng.withdraw(rid0) is False          # done

    tel = mgr.telemetry
    _assert_reconciled(tel)
    assert tel.spans.totals == {"complete": 1, "withdrawn": 1}
    wd = next(sp for sp in tel.spans.closed if sp.rid == rid1)
    assert wd.outcome == "withdrawn" and wd.e2e_cycles == 0


def test_quarantine_mid_run_closes_spans_evicted():
    """Quarantining a tenant mid-continuous-run terminates every one of
    its spans (queued and in-flight) as ``evicted``; co-tenant spans
    complete; eviction then drops the per-tenant ledger row while class
    aggregates survive."""
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("good", 1)
    eng.register_tenant("rogue", 1)
    p = _prompts(4)
    good_rids = [eng.submit("good", p[0], max_new=6),
                 eng.submit("good", p[1], max_new=1, arrive=4)]
    rogue_rids = [eng.submit("rogue", p[2], max_new=6),
                  eng.submit("rogue", p[3], max_new=6, arrive=3)]

    drains = {"n": 0}
    orig = mgr.run_queued

    def wrapped(*a, **k):
        res = orig(*a, **k)
        drains["n"] += 1
        if drains["n"] == 2:
            eng.quarantine_tenant("rogue", reason="test")
        return res

    mgr.run_queued = wrapped
    try:
        out = serve_continuous([eng], max_new_tokens=8)[0]
    finally:
        mgr.run_queued = orig

    assert set(good_rids) <= set(out)
    assert not (set(rogue_rids) & set(out))

    tel = mgr.telemetry
    _assert_reconciled(tel)
    assert tel.spans.totals["complete"] == 2
    assert tel.spans.totals["evicted"] == 2
    for sp in tel.spans.closed:
        assert sp.outcome == ("evicted" if sp.tenant == "rogue"
                              else "complete")
    assert tel.spans.to_dict()["classes"]["unclassified"]["causes"] \
        == {"evicted": 2}

    # eviction reclaims the tenant: per-tenant row gone, class history
    # (and the closed spans) retained
    assert "rogue" in tel.spans.by_tenant
    eng.evict_tenant("rogue")
    assert "rogue" not in tel.spans.by_tenant
    assert tel.spans.totals["evicted"] == 2


# --------------------------------------------------------------------- #
# Off-mode byte-identity                                                #
# --------------------------------------------------------------------- #
def _cont_tokens(telemetry):
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      paged=True, telemetry=telemetry)
    eng.register_tenant("t", 2)
    rids = [eng.submit("t", p, max_new=2 + i % 2, arrive=i // 2)
            for i, p in enumerate(_prompts(4))]
    out = serve_continuous([eng], max_new_tokens=4)[0]
    return [out[r] for r in rids], eng


def test_telemetry_off_records_nothing_and_tokens_identical():
    """With telemetry off the span plumbing is compiled in but inert:
    no spans allocated, no ledger state, and the generated tokens are
    identical to the telemetry-on run."""
    toks_on, eng_on = _cont_tokens(True)
    toks_off, eng_off = _cont_tokens(False)
    assert toks_on == toks_off

    tel_off = eng_off.manager.telemetry
    assert not tel_off.enabled
    assert eng_off._spans == {}
    assert tel_off.spans.open_count() == 0
    assert len(tel_off.spans.closed) == 0
    assert tel_off.spans.totals == {}
    assert len(tel_off.trace) == 0
    assert eng_on.manager.telemetry.spans.totals == {"complete": 4}


def test_ledger_methods_none_tolerant():
    """Every SpanLedger entry point is a no-op on None / disabled — the
    serve hot paths call them unguarded."""
    tel = Telemetry(enabled=False)
    led = tel.spans
    assert led.open("t", 0) is None
    led.begin(None)
    led.phase(None, "decode")
    led.close(None, "complete")
    led.forget_tenant("t")
    assert led.open_count() == 0 and led.totals == {}
    assert led.to_dict()["completed"] == 0

    # double-close is idempotent (quarantine + leave both fire)
    tel_on = Telemetry(enabled=True)
    sp = tel_on.spans.open("t", 0)
    tel_on.spans.close(sp, "evicted")
    tel_on.spans.close(sp, "complete")
    assert tel_on.spans.totals == {"evicted": 1}


# --------------------------------------------------------------------- #
# Export: Perfetto tracks + ring-overflow accounting                    #
# --------------------------------------------------------------------- #
def test_perfetto_per_request_tracks_and_flow_events():
    mgr = make_shared_manager(1, max_batch=2, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=2, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("t", 2)
    rid = eng.submit("t", _prompts(1)[0], max_new=3)
    serve_continuous([eng], max_new_tokens=3)

    tel = mgr.telemetry
    chrome = tel.trace.to_chrome()
    evs = chrome["traceEvents"]
    tracks = {e["args"]["name"] for e in evs if e.get("ph") == "M"
              and e["name"] == "thread_name"}
    assert f"t:r{rid}" in tracks

    sp = tel.spans.closed[-1]
    flows = [e for e in evs if e.get("cat") == "guardian.flow"
             and e["id"] == sp.sid]
    # one outgoing flow at submit, one incoming at the request track
    assert [e["ph"] for e in flows] == ["s", "f"]
    assert flows[1]["bp"] == "e"
    # phase slices are complete events on the request's own track
    rtid = next(e["tid"] for e in evs if e.get("ph") == "M"
                and e["name"] == "thread_name"
                and e["args"]["name"] == f"t:r{rid}")
    slices = [e for e in evs if e.get("tid") == rtid
              and e.get("ph") == "X"]
    assert {e["name"] for e in slices} <= set(SPAN_PHASES)
    assert sum(e["args"]["cycles"] for e in slices) == sp.e2e_cycles


def test_event_trace_counts_ring_drops():
    tr = EventTrace(capacity=2)
    for i in range(5):
        tr.emit(f"e{i}", "t", i)
    assert len(tr) == 2 and tr.emitted == 5 and tr.dropped == 3
    tr2 = EventTrace(capacity=8)
    tr2.emit("only", "t", 0)
    assert tr2.dropped == 0


def test_dashboard_renders_spans_ledger_and_overflow_warning():
    """metrics_report() carries the ledger + dropped counter and the
    dashboard renders the new tenant columns, the slo-ledger section,
    and the ring-overflow warning."""
    mgr = make_shared_manager(2, max_batch=1, paged=True, max_len=64)
    eng = ServeEngine(CFG, max_batch=1, max_len=64, seed=0,
                      manager=mgr, paged=True)
    eng.register_tenant("lc", 1,
                        tenant_class=TenantClassPolicy.latency_critical(
                            queue_age_budget=16))
    eng.submit("lc", _prompts(1)[0], max_new=2)
    serve_continuous([eng], max_new_tokens=2)

    report = mgr.metrics_report()
    assert report["slo"]["completed"] == 1
    assert report["slo"]["classes"]["latency_critical"]["attained"] == 1
    assert report["trace"]["dropped"] == 0
    row = report["tenants"]["lc"]
    assert row["slo"]["attained"] == 1
    assert row["latency"]["count"] == 1

    text = format_report(report)
    assert "slo ledger" in text
    assert "e2e50" in text and "slo%" in text
    assert "latency_critical" in text
    assert "100.0%" in text
    assert "dropped" not in text        # no overflow -> no warning

    mgr.telemetry.trace.dropped = 7
    text = format_report(mgr.metrics_report())
    assert "7 dropped (ring overflow" in text
