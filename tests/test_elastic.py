"""Elastic partition subsystem (core/elastic.py + core/pressure.py):
admission waitlist, live grow/shrink, on-device compaction — and the
churn proof: a fragmented arena rejects a tenant before compaction and
admits it after, with surviving tenants' data and serve generations
byte-identical to a no-compaction run."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (
    AdmissionStatus,
    ElasticError,
    ElasticPolicy,
    ElasticState,
    Ewma,
    FencePolicy,
    GuardianManager,
    PressureTracker,
)
from repro.core.partition import (
    BuddyAllocator,
    IntraPartitionAllocator,
    OutOfArenaMemory,
    Partition,
)


def bump(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


# ---------------------------------------------------------------------------
# Buddy/bounds elastic primitives
# ---------------------------------------------------------------------------


def test_buddy_grow_in_place_requires_free_aligned_buddy():
    alloc = BuddyAllocator(64)
    a, _ = alloc.alloc(16)            # [0,16)
    b, _ = alloc.alloc(16)            # [16,32)
    assert alloc.grow_in_place(a) is None      # buddy [16,32) occupied
    alloc.free(b)
    assert alloc.grow_in_place(a) == 32        # absorbs [16,32)
    # [32,64) is free; but a is now 32-sized at base 0 -> buddy free
    assert alloc.grow_in_place(a) == 64
    assert alloc.grow_in_place(a) is None      # whole arena: no further
    c_base = a
    alloc.free(c_base)
    assert alloc.free_slots() == 64


def test_buddy_grow_refuses_misaligned_base():
    alloc = BuddyAllocator(64)
    alloc.alloc(16)                   # [0,16)
    b, _ = alloc.alloc(16)            # [16,32): base not aligned to 32
    assert alloc.grow_in_place(b) is None
    assert alloc._allocated[b] == 4   # untouched


def test_buddy_shrink_in_place_frees_upper_buddies():
    alloc = BuddyAllocator(64)
    a, _ = alloc.alloc(32)            # [0,32)
    assert alloc.shrink_in_place(a, 8) == 8
    assert alloc.free_slots() == 64 - 8
    # the vacated [8,16) and [16,32) coalesce with nothing illegal:
    # a fresh 16-alloc lands in [16,32)
    b, got = alloc.alloc(16)
    assert (b, got) == (16, 16)
    assert alloc.largest_free_block() == 32    # [32,64)


def test_bounds_grow_shrink_relocate_preserve_invariants():
    """I1 (pow2 size) and I2 (size-aligned base) survive every elastic
    resize — the Partition constructor enforces them, so constructing
    the resized partitions at all is the assertion."""
    from repro.core.partition import PartitionBoundsTable
    table = PartitionBoundsTable(256)
    table.create("a", 16)
    table.create("b", 16)
    assert table.grow("a") is None             # b occupies the buddy
    new = table.grow("b")                      # relocation is elastic's job
    assert new is None or new.base % new.size == 0
    shrunk = table.shrink("a", 4)
    assert (shrunk.base, shrunk.size) == (0, 4)
    old, moved = table.relocate("a", 8)
    assert moved.size == 8 and moved.base % 8 == 0
    assert old.base == 0
    table.release_old(old)
    assert table.lookup("a") is moved


def _repack_case(allocs, frees):
    part = Partition(tenant_id="t", base=0, size=64)
    sub = IntraPartitionAllocator(part)
    ptrs = [sub.alloc(n) for n in allocs]
    for i in frees:
        sub.free(ptrs[i])
    return sub, [p for i, p in enumerate(ptrs) if i not in frees], \
        [n for i, n in enumerate(allocs) if i not in frees]


def _check_repack(allocs, frees):
    sub, live_bases, live_lens = _repack_case(allocs, frees)
    plan = sub.repack_plan()
    # moves ascend and pack downward: sequential copy is overlap-safe
    prev_new = -1
    for old, new, ln in plan:
        assert new <= old
        assert new > prev_new
        prev_new = new
    remap = {o: n for o, n, _ in plan}
    total = sum(live_lens)
    # the packed layout is contiguous from 0
    cursor = 0
    for b, ln in sorted(zip([remap.get(b, b) for b in live_bases],
                            live_lens)):
        assert b == cursor
        cursor += ln
    assert cursor == total
    sub.commit_repack(sub.part, plan)
    assert sub.live_span() == total
    # post-repack allocator still serves from the reclaimed tail
    if total < 64:
        assert sub.alloc(64 - total) == total


def test_repack_plan_sweep():
    cases = [
        ((8, 8, 8), (1,)),
        ((4, 4, 4, 4), (0, 2)),
        ((16, 8, 4), ()),
        ((2, 2, 2, 2, 2), (0, 1, 3)),
        ((10, 6, 10), (1,)),
    ]
    for allocs, frees in cases:
        _check_repack(allocs, frees)


@settings(max_examples=30, deadline=None)
@given(
    allocs=st.lists(st.integers(min_value=1, max_value=8), min_size=1,
                    max_size=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_repack_plan_property(allocs, seed):
    if sum(allocs) > 64:
        return
    rng = np.random.default_rng(seed)
    k = int(rng.integers(0, len(allocs) + 1))
    frees = tuple(sorted(rng.choice(len(allocs), size=k,
                                    replace=False).tolist()))
    _check_repack(tuple(allocs), frees)


# ---------------------------------------------------------------------------
# Pressure substrate
# ---------------------------------------------------------------------------


def test_ewma_seeds_then_smooths():
    ew = Ewma(alpha=0.5)
    assert ew.update(4.0) == 4.0               # seeded, not biased to 0
    assert ew.update(0.0) == 2.0
    assert ew.update(2.0) == 2.0


def test_pressure_tracker_dirty_gate_and_shrinkability():
    tr = PressureTracker()
    assert tr.sample(lambda t: None) == []     # clean: no per-tenant work
    tr.note_alloc("a")
    tr.observe("srv", 3, 8)
    samples = {s.tenant_id: s for s in tr.sample(
        lambda t: (4, 16) if t == "a" else None)}
    assert samples["a"].shrinkable and samples["a"].utilization == 0.25
    assert not samples["srv"].shrinkable
    assert samples["srv"].live == 3 and samples["srv"].size == 8
    assert not tr.dirty and tr.sample(lambda t: (4, 16)) == []


def test_pressure_failures_reported_once():
    tr = PressureTracker()
    tr.note_failure("a")
    (s,) = tr.sample(lambda t: (16, 16))
    assert s.failures == 1
    tr.note_alloc("a")
    (s,) = tr.sample(lambda t: (16, 16))
    assert s.failures == 0                     # consumed by the first sample


# ---------------------------------------------------------------------------
# Admission control + waitlist
# ---------------------------------------------------------------------------


def test_admit_waitlists_instead_of_failing_and_readmits_on_departure():
    mgr = GuardianManager(total_slots=64)
    a = mgr.elastic.admit("a", 32)
    b = mgr.elastic.admit("b", 32)
    assert a.status is AdmissionStatus.ADMITTED
    assert b.status is AdmissionStatus.ADMITTED
    c = mgr.elastic.admit("c", 16)
    assert c.status is AdmissionStatus.WAITLISTED
    assert c.client is None
    assert mgr.elastic.state_of("c") is ElasticState.WAITLISTED
    # a departure re-drives admission from the waitlist
    mgr.remove_tenant("b")
    assert c.status is AdmissionStatus.ADMITTED
    assert c.client is not None
    assert mgr.elastic.state_of("c") is ElasticState.ACTIVE
    assert mgr.bounds.lookup("c").size == 16


def test_waitlist_backfill_fills_holes_the_head_cannot_use():
    """FIFO with backfill: the head keeps first claim on freed capacity
    (and exclusive compaction rights), but a small tenant is never
    head-of-line blocked behind a large one when a hole the head cannot
    use is available."""
    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("a", 32)
    mgr.elastic.admit("b", 16)                 # 16 slots left free
    big = mgr.elastic.admit("big", 32)         # does not fit: head
    small = mgr.elastic.admit("small", 8)      # fits in the leftover 16
    assert big.status is AdmissionStatus.WAITLISTED
    assert small.status is AdmissionStatus.ADMITTED   # backfilled
    mgr.remove_tenant("a")                     # head claims the 32 first
    assert big.status is AdmissionStatus.ADMITTED
    assert mgr.bounds.lookup("big").size == 32


def test_quarantine_eviction_triggers_waitlist_readmission():
    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("good", 32)
    rogue = mgr.elastic.admit("rogue", 32)
    assert rogue.status is AdmissionStatus.ADMITTED
    waiting = mgr.elastic.admit("waiting", 32)
    assert waiting.status is AdmissionStatus.WAITLISTED
    mgr.quarantine.quarantine("rogue", reason="test")
    assert waiting.status is AdmissionStatus.WAITLISTED  # partition kept
    mgr.quarantine.evict("rogue")
    assert waiting.status is AdmissionStatus.ADMITTED


def test_admission_shrinks_idle_reservations_below_low_watermark():
    mgr = GuardianManager(
        total_slots=64,
        elastic_policy=ElasticPolicy(min_slots=4, low_watermark=0.25))
    idle = mgr.elastic.admit("idle", 32)
    mgr.elastic.admit("busy", 32)
    p = idle.client.malloc(2)                  # 2/32 live: deeply idle
    idle.client.memcpy_h2d(p, np.full(2, 5.0, np.float32))
    idle.client.synchronize()
    # EWMA needs a sample history before admission may steal the reserve
    mgr.elastic.poll()
    adm = mgr.elastic.admit("newcomer", 16)
    assert adm.status is AdmissionStatus.ADMITTED
    assert mgr.bounds.lookup("idle").size < 32
    np.testing.assert_array_equal(idle.client.memcpy_d2h(p, 2),
                                  np.full(2, 5.0, np.float32))


# ---------------------------------------------------------------------------
# Live grow/shrink + pointer translation
# ---------------------------------------------------------------------------


def test_malloc_grows_partition_on_failure_and_old_ptrs_survive():
    mgr = GuardianManager(
        total_slots=128,
        elastic_policy=ElasticPolicy(grow_on_failure=True))
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    p1 = a.malloc(12)
    a.memcpy_h2d(p1, np.arange(12, dtype=np.float32))
    a.synchronize()
    p2 = a.malloc(10)                          # 22 > 16: grows (relocates)
    part = mgr.bounds.lookup("a")
    assert part.size == 32
    a.memcpy_h2d(p2, np.full(10, 7.0, np.float32))
    # ptr minted before the move still resolves (translated at use)
    np.testing.assert_array_equal(a.memcpy_d2h(p1, 12),
                                  np.arange(12, dtype=np.float32))
    np.testing.assert_array_equal(a.memcpy_d2h(p2, 10),
                                  np.full(10, 7.0, np.float32))


def test_launch_with_pre_move_ptr_lands_in_new_extent():
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    a.module_load("bump", bump)
    p = a.malloc(4)
    a.memcpy_h2d(p, np.zeros(4, np.float32))
    a.synchronize()
    mgr.elastic.relocate("a", 16)
    a.launch_kernel("bump", ptrs=[p], args=(4,))   # pre-move handle
    a.synchronize()
    np.testing.assert_array_equal(a.memcpy_d2h(p, 4),
                                  np.ones(4, np.float32))
    # and the write landed inside the NEW extent, not the old one
    part = mgr.bounds.lookup("a")
    own = np.asarray(mgr.arena.unsafe_read_range(part.base, part.size))
    assert (own == 1.0).sum() == 4


def test_malloc_raises_by_default_without_grow_opt_in():
    """No elastic opt-in: the paper's reserve-at-init semantics hold —
    over-malloc fails instead of silently consuming arena headroom."""
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 16)
    a.malloc(16)
    with pytest.raises(OutOfArenaMemory):
        a.malloc(1)
    assert mgr.bounds.lookup("a").size == 16


def test_ptr_epochs_prevent_reused_address_aliasing():
    """A repack can hand a NEW allocation the address an old handle was
    minted at.  Translation is keyed by mint epoch, so the fresh ptr
    resolves to itself while the stale handle still chases its moved
    data — no aliasing."""
    mgr = GuardianManager(total_slots=64)
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    x = a.malloc(4)                            # rel 0
    y = a.malloc(4)                            # rel 4
    a.memcpy_h2d(y, np.full(4, 2.0, np.float32))
    a.synchronize()
    a.free(x)
    mgr.elastic.shrink("a", 8)                 # repack: y moves rel 4 -> 0
    z = a.malloc(4)                            # rel 4: y's MINTED address
    assert z.addr == y.addr and z.epoch != y.epoch
    a.memcpy_h2d(z, np.full(4, 9.0, np.float32))
    # each handle reaches its own storage
    np.testing.assert_array_equal(a.memcpy_d2h(y, 4),
                                  np.full(4, 2.0, np.float32))
    np.testing.assert_array_equal(a.memcpy_d2h(z, 4),
                                  np.full(4, 9.0, np.float32))


def test_auto_resize_poll_grows_under_pressure_and_shrinks_idle():
    mgr = GuardianManager(
        total_slots=256,
        elastic_policy=ElasticPolicy(auto_resize=True, min_slots=8,
                                     high_watermark=0.85,
                                     low_watermark=0.25))
    a = mgr.register_tenant("a", 32)
    mgr.register_tenant("b", 32)
    ptrs = [a.malloc(8) for _ in range(4)]     # 32/32 live
    for _ in range(3):
        mgr.elastic.poll()
        mgr.elastic.pressure.note_alloc("a")
    assert mgr.bounds.lookup("a").size > 32    # grew under pressure
    for p in ptrs[1:]:
        a.free(p)                              # 8 live of >= 64
    for _ in range(8):
        mgr.elastic.poll()
        mgr.elastic.pressure.note_free("a")
    # shrank (halving per poll) until utilization left the idle band:
    # 8 live of 16 = 0.5 >= low watermark, so 16 is the fixpoint
    assert mgr.bounds.lookup("a").size == 16
    np.testing.assert_array_equal(
        a.memcpy_d2h(ptrs[0], 8), np.zeros(8, np.float32))


def test_resize_refused_while_tenant_has_queued_work():
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    a.module_load("bump", bump)
    p = a.malloc(4)
    a.memcpy_h2d(p, np.zeros(4, np.float32))   # queued (SPATIAL): busy
    with pytest.raises(ElasticError):
        mgr.elastic.relocate("a", 32)
    a.synchronize()
    assert mgr.elastic.relocate("a", 32).size == 32


def test_grow_in_place_never_needs_idle_tenant():
    """An in-place grow moves no data, so it is legal even with work
    queued — the base never changes, staged operands stay valid."""
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 16)           # [0,16), buddy [16,32) free
    a.module_load("bump", bump)
    p = a.malloc(4)
    a.memcpy_h2d(p, np.zeros(4, np.float32))   # queued: tenant busy
    new = mgr.elastic.grow("a")
    assert (new.base, new.size) == (0, 32)
    a.synchronize()
    np.testing.assert_array_equal(a.memcpy_d2h(p, 4),
                                  np.zeros(4, np.float32))


# ---------------------------------------------------------------------------
# Compaction churn proof (acceptance criteria)
# ---------------------------------------------------------------------------


def test_churn_compaction_admits_after_reject_raw_launch_plane():
    """admit/depart/grow across 4 tenants fragments the arena; a static
    register is rejected; one compaction pass admits it — and the
    surviving tenants' arena bytes are exactly what a no-compaction run
    produced (relocation is invisible)."""
    def run(compaction: bool):
        mgr = GuardianManager(total_slots=64)
        clients = {}
        for t, n in (("a", 16), ("b", 16), ("c", 16)):
            clients[t] = mgr.elastic.admit(t, n).client
            clients[t].module_load("bump", bump)
        ptrs = {}
        for i, (t, c) in enumerate(clients.items()):
            ptrs[t] = c.malloc(8)
            c.memcpy_h2d(ptrs[t], np.full(8, float(i + 1), np.float32))
            c.launch_kernel("bump", ptrs=[ptrs[t]], args=(8,))
        mgr.synchronize()
        mgr.remove_tenant("b")                 # free [16,32) + [48,64)
        del clients["b"], ptrs["b"]
        if compaction:
            with pytest.raises(OutOfArenaMemory):
                mgr.bounds.create("d", 32)     # fragmented: static reject
            adm = mgr.elastic.admit("d", 32)   # shrink/compact makes room
            assert adm.status is AdmissionStatus.ADMITTED
            assert mgr.elastic.stats["compactions"] >= 1
        # surviving tenants' data, read back through their (possibly
        # translated) handles
        return {t: np.asarray(c.memcpy_d2h(ptrs[t], 8))
                for t, c in clients.items()}

    with_c = run(compaction=True)
    without = run(compaction=False)
    assert set(with_c) == {"a", "c"}
    for t in with_c:
        np.testing.assert_array_equal(with_c[t], without[t])
        np.testing.assert_array_equal(
            with_c[t], np.full(8, float({"a": 1, "c": 3}[t]) + 1.0,
                               np.float32))


def test_compaction_scrubs_vacated_extents():
    mgr = GuardianManager(total_slots=64)
    a = mgr.elastic.admit("a", 16).client
    b = mgr.elastic.admit("b", 16).client
    c = mgr.elastic.admit("c", 16).client
    pc = c.malloc(16)
    c.memcpy_h2d(pc, np.full(16, 9.0, np.float32))
    c.synchronize()
    mgr.remove_tenant("b")
    old = mgr.bounds.lookup("c")
    assert mgr.elastic.compact() == 1          # c moves down into b's hole
    new = mgr.bounds.lookup("c")
    assert new.base < old.base
    # the vacated extent handed back zeroed (no cross-tenant leak)
    left = np.asarray(mgr.arena.unsafe_read_range(old.base, old.size))
    np.testing.assert_array_equal(left, np.zeros(old.size, np.float32))
    np.testing.assert_array_equal(c.memcpy_d2h(pc, 16),
                                  np.full(16, 9.0, np.float32))


def test_churn_compaction_serve_generations_byte_identical():
    """The serving-plane churn proof: tenants admit/depart/grow on a
    shared KV pool; the fragmented pool rejects a tenant until a
    compaction pass relocates a survivor's slots (pool moved through the
    trusted relocation step); the survivors' subsequent generations are
    byte-identical to a run that never compacted."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(11)
    prompts = {t: rng.integers(0, cfg.vocab, 10, np.int32)
               for t in ("t0", "t1", "t2")}
    round2 = {t: rng.integers(0, cfg.vocab, 10, np.int32)
              for t in ("t0", "t2", "t3")}

    def run(compaction: bool):
        eng = ServeEngine(cfg, max_batch=8, max_len=64)
        for t in ("t0", "t1", "t2"):
            eng.register_tenant(t, 2)
        rids = {t: eng.submit(t, p) for t, p in prompts.items()}
        out1 = eng.run(max_new_tokens=4)
        gens = {t: out1[r] for t, r in rids.items()}
        eng.manager.remove_tenant("t1")        # fragment: [free][t2][free]
        if compaction:
            with pytest.raises(OutOfArenaMemory):
                eng.manager.bounds.create("t3", 4)   # static reject
            adm = eng.manager.elastic.admit("t3", 4)
            assert adm.status is AdmissionStatus.ADMITTED
            assert eng.manager.elastic.stats["relocations"] >= 1
            eng._tenants.add("t3")
            rid3 = eng.submit("t3", round2["t3"])
        rids2 = {t: eng.submit(t, round2[t]) for t in ("t0", "t2")}
        out2 = eng.run(max_new_tokens=4)
        gens2 = {t: out2[r] for t, r in rids2.items()}
        if compaction:
            assert len(out2[rid3]) == 4        # the admitted tenant serves
        return gens, gens2

    gens_c, gens2_c = run(compaction=True)
    gens_n, gens2_n = run(compaction=False)
    assert gens_c == gens_n                    # pre-churn identical
    assert gens2_c == gens2_n                  # survivors unperturbed


# ---------------------------------------------------------------------------
# State machine + stats surface
# ---------------------------------------------------------------------------


def test_elastic_states_follow_the_design_machine():
    mgr = GuardianManager(total_slots=64)
    adm = mgr.elastic.admit("a", 16)
    assert mgr.elastic.state_of("a") is ElasticState.ACTIVE
    mgr.register_tenant("b", 16)
    seen = []
    mgr.elastic.subscribe(
        lambda ev: seen.append((ev.kind, mgr.elastic.state_of(ev.tenant_id))))
    mgr.elastic.relocate("a", 16)
    assert seen and seen[0][0] == "relocate"
    assert seen[0][1] is ElasticState.RESIZING   # mid-transition
    assert mgr.elastic.state_of("a") is ElasticState.ACTIVE
    mgr.remove_tenant("a")
    assert mgr.elastic.state_of("a") is None


def test_elastic_events_and_stats_accumulate():
    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("a", 16)
    w = mgr.elastic.admit("w", 64)
    assert w.status is AdmissionStatus.WAITLISTED
    assert mgr.elastic.stats["admitted"] == 1
    assert mgr.elastic.stats["waitlisted"] == 1
    assert any(e.startswith("admit a") for e in mgr.elastic.events)
    assert any(e.startswith("waitlist w") for e in mgr.elastic.events)


# ---------------------------------------------------------------------------
# Review regressions: dedupe, shrink guard, withdraw, placement probe
# ---------------------------------------------------------------------------


def test_buddy_peek_alloc_mirrors_alloc_choice():
    alloc = BuddyAllocator(64)
    a, _ = alloc.alloc(16)
    b, _ = alloc.alloc(8)
    alloc.free(a)
    for size in (4, 8, 16, 32):
        peek = alloc.peek_alloc(size)
        base, got = alloc.alloc(size)
        assert peek == base, (size, peek, base)
        alloc.free(base)
    assert alloc.peek_alloc(128) is None


def test_relocate_refuses_extent_too_small_for_live_data():
    """A destination too small for the live allocations must fail
    *before* any device work — the data stays byte-intact in place."""
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 16)
    p = a.malloc(40)
    a.memcpy_h2d(p, np.arange(40, dtype=np.float32))
    a.synchronize()
    with pytest.raises(ElasticError):
        mgr.elastic.relocate("a", 32)          # 40 live > 32
    part = mgr.bounds.lookup("a")
    assert part.size == 64                     # bounds untouched
    np.testing.assert_array_equal(a.memcpy_d2h(p, 40),
                                  np.arange(40, dtype=np.float32))


def test_withdraw_removes_waitlisted_tenant_before_admission():
    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("a", 64)
    w = mgr.elastic.admit("w", 16)
    assert w.status is AdmissionStatus.WAITLISTED
    assert mgr.elastic.withdraw("w")
    assert not mgr.elastic.withdraw("w")       # idempotent
    mgr.remove_tenant("a")                     # would have admitted w
    assert w.status is AdmissionStatus.WAITLISTED
    assert mgr.elastic.state_of("w") is None
    assert not mgr.elastic.withdraw("a")       # admitted: not withdrawable


def test_shared_pool_relocation_dispatches_once_across_engines():
    """Two co-hosted engines both serving a tenant each observe its
    resize, but the shared pool must move exactly ONCE — a second
    copy-then-zero pass would wipe the just-moved KV slots."""
    from repro.configs import get_config
    from repro.launch.serve import (
        ServeEngine,
        make_shared_manager,
        serve_engines,
    )

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(13)
    mgr = make_shared_manager(2, max_batch=4)
    engines = [ServeEngine(cfg, max_batch=4, max_len=64, manager=mgr)
               for _ in range(2)]
    engines[0].register_tenant("a", 2)
    r0 = engines[0].submit("a", rng.integers(0, cfg.vocab, 8, np.int32))
    r1 = engines[1].submit("a", rng.integers(0, cfg.vocab, 8, np.int32))
    outs = serve_engines(engines, max_new_tokens=2)
    assert len(outs[0][r0]) == 2 and len(outs[1][r1]) == 2
    old = mgr.bounds.lookup("a")
    pool = engines[0]._pool.buf
    k = next(iter(pool.values())) if isinstance(pool, dict) else pool
    before = np.asarray(engines[0]._pool.buf["k"]
                        [:, old.base:old.base + old.size]).copy()
    assert (before != 0).any()                 # the tenant wrote KV
    new = mgr.elastic.relocate("a", old.size)  # both engines notified
    after = np.asarray(engines[0]._pool.buf["k"]
                       [:, new.base:new.base + new.size])
    np.testing.assert_array_equal(before, after)   # moved, not wiped


def test_ptr_translation_survives_unrelated_moves_between_epochs():
    """A ptr minted in an old epoch whose block sat still through later
    epochs must still translate when a NEWER move finally relocates it
    (the remap folds into every epoch's table, not just the current
    one)."""
    mgr = GuardianManager(total_slots=128)
    a = mgr.register_tenant("a", 32)
    mgr.register_tenant("b", 32)
    p_still = a.malloc(4)                      # rel 0: epoch 0
    gap = a.malloc(4)                          # rel 4
    p_move = a.malloc(4)                       # rel 8
    a.memcpy_h2d(p_still, np.full(4, 1.0, np.float32))
    a.memcpy_h2d(p_move, np.full(4, 3.0, np.float32))
    a.synchronize()
    a.free(gap)
    mgr.elastic.shrink("a", 8)                 # epoch 1: moves p_move only
    mgr.elastic.relocate("a", 8)               # epoch 2: moves EVERYTHING
    np.testing.assert_array_equal(a.memcpy_d2h(p_still, 4),
                                  np.full(4, 1.0, np.float32))
    np.testing.assert_array_equal(a.memcpy_d2h(p_move, 4),
                                  np.full(4, 3.0, np.float32))
    a.free(p_still)                            # epoch-0 handle still frees


def test_banned_id_admission_rejects_without_wedging_the_waitlist():
    """A banned (evicted) id on the waitlist is REJECTED — it neither
    blocks co-waiting tenants nor aborts the drain a departure
    triggered."""
    from repro.core import FencePolicy

    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("a", 32)
    mgr.elastic.admit("rogue", 16)
    mgr.quarantine.quarantine("rogue", reason="t")
    mgr.quarantine.evict("rogue")              # id now banned; 32 free
    banned = mgr.elastic.admit("rogue", 8)     # attempted: ban rejects
    assert banned.status is AdmissionStatus.REJECTED
    w = mgr.elastic.admit("w", 64)             # true capacity wait
    assert w.status is AdmissionStatus.WAITLISTED
    # bad arguments reject on attempt instead of waitlisting forever
    bad = mgr.elastic.admit("npol", 8, policy=FencePolicy.NONE)
    assert bad.status is AdmissionStatus.REJECTED
    mgr.remove_tenant("a")                     # re-drives the waitlist
    assert w.status is AdmissionStatus.ADMITTED   # not dropped, not wedged
    assert not mgr.elastic.waitlist


def test_relocation_scrub_ranges_validated_against_extents():
    from repro.launch.steps import build_flat_relocation_step

    with pytest.raises(ValueError):
        build_flat_relocation_step(
            moves=(), zeros=((64, 16),),       # outside both extents
            src_extent=(0, 16), dst_extent=(32, 16))
    # in-extent scrubs build fine
    build_flat_relocation_step(
        moves=((0, 32, 8),), zeros=((0, 16),),
        src_extent=(0, 16), dst_extent=(32, 16))


def test_relocation_with_repack_copies_already_packed_blocks():
    """A block already sitting at its packed offset is absent from the
    repack plan, but it still has to cross to the new extent — the old
    one is being vacated and scrubbed."""
    mgr = GuardianManager(
        total_slots=128,
        elastic_policy=ElasticPolicy(grow_on_failure=True))
    a = mgr.register_tenant("a", 16)
    mgr.register_tenant("b", 16)
    front = a.malloc(4)                        # rel 0: already packed
    mid = a.malloc(4)                          # rel 4: freed below
    tail = a.malloc(4)                         # rel 8: plan moves it
    a.memcpy_h2d(front, np.full(4, 1.0, np.float32))
    a.memcpy_h2d(tail, np.full(4, 3.0, np.float32))
    a.synchronize()
    a.free(mid)
    mgr.elastic.relocate("a", 8)               # span 12 > 8: repack path
    np.testing.assert_array_equal(a.memcpy_d2h(front, 4),
                                  np.full(4, 1.0, np.float32))
    np.testing.assert_array_equal(a.memcpy_d2h(tail, 4),
                                  np.full(4, 3.0, np.float32))


def test_duplicate_admit_of_live_tenant_rejects_without_state_damage():
    mgr = GuardianManager(total_slots=64)
    mgr.elastic.admit("a", 16)
    assert mgr.elastic.state_of("a") is ElasticState.ACTIVE
    dup = mgr.elastic.admit("a", 8)
    assert dup.status is AdmissionStatus.REJECTED
    assert mgr.elastic.state_of("a") is ElasticState.ACTIVE  # untouched
    assert mgr.bounds.lookup("a").size == 16


def test_pool_relocation_skips_tensors_short_of_either_extent():
    """A tensor long enough for the source range but short of the
    destination range is a meta-shaped straggler: it must pass through
    untouched, not be clamp-written at the wrong rows."""
    from repro.launch.steps import build_pool_relocation_step

    fn = build_pool_relocation_step(src=0, dst=48, size=16)
    pool = {"short": jnp.arange(56, dtype=jnp.float32).reshape(1, 56),
            "full": jnp.ones((1, 64, 2), jnp.float32)}
    # short: axis-1 = 56 covers [src, src+16) but NOT [dst, dst+16) —
    # the old source-only guard would clamp-write it at row 40
    _, new_pool, _ = fn(None, pool)
    np.testing.assert_array_equal(np.asarray(new_pool["short"]),
                                  np.asarray(pool["short"]))
    # the genuinely slot-indexed tensor moved: source zeroed, dst set
    full = np.asarray(new_pool["full"])
    assert (full[:, 0:16] == 0).all() and (full[:, 48:64] == 1).all()
