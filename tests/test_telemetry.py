"""Flight recorder (core/telemetry.py) + operator dashboard
(launch/dashboard.py, repro.top) — DESIGN.md §Observability.

Covers: Histogram percentile semantics, MetricsRegistry per-tenant
series + the ``enabled=False`` no-op discipline, EventTrace ring buffer
and Chrome/Perfetto export (round-trips ``json.loads`` with per-track
monotonic timestamps), the manager-plane instrumentation (drain cycles,
queue age, quarantine gauges, lifecycle events), and the three headline
invariants:

* logical metrics are **bit-identical** between ``jit_steps=True`` and
  ``jit_steps=False`` serve runs (wall-clock series are excluded via
  ``snapshot(include_timing=False)``);
* ``telemetry=False`` is byte-identical on the data plane and leaves
  the registry/trace empty;
* telemetry adds **zero device syncs** to fenced (BITWISE) traffic —
  the ViolationLog dirty-flag discipline is untouched.

Deterministic sweeps mirror every hypothesis property (tier-1 runs
without hypothesis; see tests/_hyp.py).
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import (
    EventTrace,
    FencePolicy,
    GuardianManager,
    Histogram,
    MetricsRegistry,
    ThresholdPolicy,
)
from repro.core.telemetry import (
    DRAIN_TRACK,
    GLOBAL,
    QUEUE_AGE_BOUNDS,
)
from repro.launch.dashboard import format_report, sparkline

TOTAL = 512


def bump(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def make_mgr(n_tenants=3, **kw):
    kw.setdefault("total_slots", TOTAL)
    kw.setdefault("standalone_fast_path", False)
    mgr = GuardianManager(**kw)
    clients, ptrs = [], []
    for i in range(n_tenants):
        c = mgr.register_tenant(f"t{i}", TOTAL // (2 * n_tenants))
        c.module_load("bump", bump)
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        clients.append(c)
        ptrs.append(p)
    mgr.synchronize()
    return mgr, clients, ptrs


def drive(mgr, clients, ptrs, rounds=3):
    for _ in range(rounds):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
        mgr.run_queued()


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------


def test_histogram_exact_on_edge_valued_ints():
    h = Histogram(QUEUE_AGE_BOUNDS)
    for v in (0, 1, 1, 2, 4):
        h.observe(v)
    assert h.count == 5 and h.mean == pytest.approx(1.6)
    assert h.percentile(50) == 1.0
    assert h.percentile(90) == 4.0
    assert h.percentile(99) == 4.0
    p = h.percentiles()
    assert p == {"p50": 1.0, "p90": 4.0, "p99": 4.0,
                 "count": 5.0, "mean": pytest.approx(1.6)}


def test_histogram_empty_and_overflow():
    h = Histogram((0, 1, 2))
    assert h.percentile(99) == 0.0 and h.mean == 0.0
    h.observe(10_000)                       # overflow bucket
    assert h.percentile(50) == 10_000.0     # exact observed max
    assert h.to_dict()["max"] == 10_000.0
    with pytest.raises(ValueError):
        Histogram(())
    with pytest.raises(ValueError):
        Histogram((2, 1))


def test_histogram_percentiles_monotonic_sweep():
    rng = np.random.default_rng(0)
    for _ in range(10):
        h = Histogram(QUEUE_AGE_BOUNDS)
        vals = rng.integers(0, 200, size=rng.integers(1, 40))
        for v in vals:
            h.observe(int(v))
        ps = [h.percentile(q) for q in (1, 25, 50, 75, 90, 99, 100)]
        assert ps == sorted(ps)
        assert h.percentile(100) >= vals.max() or \
            h.percentile(100) == float(vals.max())
        assert h.count == len(vals)


@given(st.lists(st.integers(min_value=0, max_value=300), min_size=1,
                max_size=40))
@settings(max_examples=30, deadline=None)
def test_histogram_percentiles_monotonic_property(vals):
    h = Histogram(QUEUE_AGE_BOUNDS)
    for v in vals:
        h.observe(v)
    ps = [h.percentile(q) for q in (1, 50, 90, 99, 100)]
    assert ps == sorted(ps)
    assert h.count == len(vals)
    # a percentile is never below the true minimum (bucket upper edges)
    assert ps[0] >= 0


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_disabled_is_a_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("x")
    reg.set_gauge("g", 1.0, tenant="a")
    reg.observe("h", 3.0)
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}
    assert reg.counter("x") == 0 and reg.gauge("g", tenant="a") is None
    assert reg.percentiles("h")["count"] == 0.0


def test_registry_per_tenant_series_and_forget():
    reg = MetricsRegistry()
    reg.inc("req", tenant="a")
    reg.inc("req", n=2, tenant="b")
    reg.inc("req")                           # global series
    reg.observe("queue_age_cycles", 3, tenant="a")
    reg.set_gauge("util", 0.5, tenant="a")
    assert reg.counter("req", tenant="a") == 1
    assert reg.counter("req", tenant="b") == 2
    assert reg.counter("req") == 1           # GLOBAL key is separate
    reg.forget_tenant("a")
    assert reg.counter("req", tenant="a") == 0
    assert reg.counter("req", tenant="b") == 2
    assert reg.gauge("util", tenant="a") is None
    assert reg.histogram("queue_age_cycles", tenant="a") is None


def test_registry_timing_series_excluded_from_logical_snapshot():
    reg = MetricsRegistry()
    reg.observe("drain_cycle_us", 123.0, timing=True)
    reg.observe("queue_age_cycles", 1)
    full = reg.snapshot(include_timing=True)["histograms"]
    logical = reg.snapshot(include_timing=False)["histograms"]
    assert "drain_cycle_us" in full
    assert "drain_cycle_us" not in logical
    assert "queue_age_cycles" in logical


def _feed(reg, ops):
    for kind, name, val, tenant in ops:
        if kind == 0:
            reg.inc(name, n=val, tenant=tenant)
        elif kind == 1:
            reg.set_gauge(name, float(val), tenant=tenant)
        else:
            reg.observe(name, float(val), tenant=tenant)


def test_registry_determinism_sweep():
    """Two registries fed the same op sequence are bit-identical — the
    substrate of the jit-vs-eager metrics comparison."""
    rng = np.random.default_rng(1)
    ops = [(int(rng.integers(0, 3)), f"m{rng.integers(3)}",
            int(rng.integers(1, 9)),
            [None, "a", "b"][rng.integers(3)]) for _ in range(200)]
    a, b = MetricsRegistry(), MetricsRegistry()
    _feed(a, ops)
    _feed(b, ops)
    assert a.snapshot() == b.snapshot()
    assert a.to_prometheus() == b.to_prometheus()


@given(st.lists(st.tuples(st.integers(0, 2), st.sampled_from("xyz"),
                          st.integers(1, 9),
                          st.sampled_from([None, "a", "b"])),
                max_size=60))
@settings(max_examples=25, deadline=None)
def test_registry_determinism_property(ops):
    a, b = MetricsRegistry(), MetricsRegistry()
    _feed(a, ops)
    _feed(b, ops)
    assert a.snapshot() == b.snapshot()
    assert a.to_prometheus() == b.to_prometheus()


def test_prometheus_exposition_shape():
    reg = MetricsRegistry()
    reg.inc("requests", n=3, tenant="a")
    reg.observe("queue_age_cycles", 1, tenant="a")
    reg.observe("queue_age_cycles", 500, tenant="a")   # overflow
    reg.set_gauge("util", 0.25)
    text = reg.to_prometheus()
    assert text.endswith("\n")
    lines = text.splitlines()
    assert '# TYPE guardian_requests_total counter' in lines
    assert 'guardian_requests_total{tenant="a"} 3' in lines
    assert "guardian_util 0.25" in lines
    # histogram triple: cumulative buckets, +Inf == count, sum
    inf = [l for l in lines if '+Inf' in l]
    assert inf == ['guardian_queue_age_cycles_bucket'
                   '{tenant="a",le="+Inf"} 2']
    assert 'guardian_queue_age_cycles_count{tenant="a"} 2' in lines
    assert 'guardian_queue_age_cycles_sum{tenant="a"} 501' in lines
    # bucket counts are cumulative (never decreasing)
    buckets = [int(l.rsplit(" ", 1)[1]) for l in lines
               if "queue_age_cycles_bucket" in l]
    assert buckets == sorted(buckets)


# ---------------------------------------------------------------------------
# EventTrace + Perfetto export
# ---------------------------------------------------------------------------


def test_trace_ring_buffer_drops_oldest():
    tr = EventTrace(capacity=4)
    for i in range(6):
        tr.emit(f"e{i}", "trk", cycle=i)
    assert len(tr) == 4 and tr.emitted == 6
    assert [e.name for e in tr.events()] == ["e2", "e3", "e4", "e5"]
    tr.clear()
    assert len(tr) == 0 and tr.emitted == 6


def test_trace_disabled_emits_nothing():
    tr = EventTrace(enabled=False)
    tr.emit("e", "trk", cycle=0)
    assert len(tr) == 0 and tr.emitted == 0


def test_chrome_export_roundtrips_with_monotonic_tracks():
    tr = EventTrace()
    tr.emit("a", "t0", cycle=0, slots=4)
    tr.emit("b", "t1", cycle=0)
    tr.emit("c", "t0", cycle=1)
    tr.emit("drain", DRAIN_TRACK, cycle=1, dur_us=5.0,
            ts_us=tr.now_us())
    doc = json.loads(tr.to_json())           # round-trips json.loads
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta}
    assert {"guardian", "t0", "t1", DRAIN_TRACK} <= names
    body = [e for e in evs if e["ph"] in ("i", "X")]
    assert all(e["cat"] == "guardian" for e in body)
    assert all("cycle" in e["args"] for e in body)
    by_tid = {}
    for e in body:
        by_tid.setdefault(e["tid"], []).append(e["ts"])
    for ts in by_tid.values():               # per-track monotonic
        assert ts == sorted(ts)
    x = [e for e in body if e["ph"] == "X"]
    assert len(x) == 1 and x[0]["dur"] == 5.0


# ---------------------------------------------------------------------------
# Manager-plane instrumentation
# ---------------------------------------------------------------------------


def test_drain_instrumentation_and_report_shape():
    mgr, clients, ptrs = make_mgr(3)
    drive(mgr, clients, ptrs, rounds=3)
    reg = mgr.telemetry.registry
    assert reg.counter("drain_cycles") > 0
    assert reg.counter("tenants_registered") == 3
    for t in ("t0", "t1", "t2"):
        assert reg.percentiles("queue_age_cycles",
                               tenant=t)["count"] == 3.0
    rep = mgr.metrics_report()
    for key in ("tenants", "scheduler", "drain", "drain_cycles",
                "launch", "jit_cache", "elastic", "memory",
                "violations", "counters", "gauges", "trace"):
        assert key in rep
    row = rep["tenants"]["t1"]
    assert row["state"] == "active"
    assert {"p50", "p90", "p99", "count"} <= set(row["queue_age"])
    assert rep["scheduler"]["queue_age"]["count"] == 9.0
    assert rep["drain"]["count"] == float(reg.counter("drain_cycles"))
    # drain-cycle duration events land on their own Perfetto track
    drains = [e for e in mgr.telemetry.trace.events()
              if e.track == DRAIN_TRACK]
    assert drains and all(e.dur_us is not None for e in drains)
    starts = [e.ts_us for e in drains]
    assert starts == sorted(starts)          # cycles never overlap


def test_legacy_reports_are_views_of_the_recorder():
    mgr, clients, ptrs = make_mgr(2)
    drive(mgr, clients, ptrs, rounds=1)
    assert mgr.violation_report() == mgr.telemetry.violation_view()
    assert mgr.jit_cache_stats() == mgr.telemetry.jit_cache_view()
    vio = mgr.violation_report()
    assert {"tenants", "transfer_violations", "events"} <= set(vio)
    jc = mgr.jit_cache_stats()
    assert {"capacity", "entries", "per_kernel", "evictions",
            "fused_capacity", "fused_entries",
            "fused_evictions"} <= set(jc)


def test_queue_age_percentiles_under_lookahead():
    """2 tenants x 2 ops with lookahead=1 dispatch as one width-4 step:
    ages (1, 1, 0, 0) -> p50=0, p90=p99=1 — exact, because ages are
    integers on bucket edges (tests the ROADMAP per-class p50/p99 row)."""
    mgr, clients, ptrs = make_mgr(2, lookahead_cycles=1)
    for _ in range(2):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    st_ = mgr.scheduler.stats
    assert st_.queue_age_percentiles() == {
        "p50": 0.0, "p90": 1.0, "p99": 1.0, "count": 4.0, "mean": 0.5}
    assert mgr.telemetry.registry.counter("lookahead_holds") >= 1
    assert any(e.name == "lookahead_flush"
               for e in mgr.telemetry.trace.events())


def test_telemetry_off_is_byte_identical_and_empty():
    arenas, snaps = [], []
    for enabled in (True, False):
        mgr, clients, ptrs = make_mgr(2, telemetry=enabled)
        drive(mgr, clients, ptrs, rounds=2)
        mgr.synchronize()
        arenas.append(np.asarray(mgr.arena.buf))
        snaps.append(mgr.telemetry.registry.snapshot())
        if not enabled:
            assert len(mgr.telemetry.trace) == 0
    np.testing.assert_array_equal(arenas[0], arenas[1])
    assert snaps[1] == {"counters": {}, "gauges": {}, "histograms": {}}


def test_zero_added_syncs_on_fenced_traffic():
    """BITWISE drains with telemetry ON must never read device memory:
    the ViolationLog stays clean and is never snapshotted (the
    dirty-flag discipline) — the record paths are host dict writes."""
    mgr, clients, ptrs = make_mgr(2, policy=FencePolicy.BITWISE)
    calls = []
    orig = mgr.violog.snapshot
    mgr.violog.snapshot = lambda: (calls.append(1), orig())[1]
    drive(mgr, clients, ptrs, rounds=3)
    assert mgr.telemetry.registry.counter("drain_cycles") > 0
    assert not calls                         # no log sync on fenced drains
    assert not mgr.violog.dirty


def test_quarantine_gauges_counters_and_events():
    mgr = GuardianManager(total_slots=TOTAL, policy=FencePolicy.CHECK,
                          standalone_fast_path=False,
                          quarantine_policy=ThresholdPolicy(
                              quarantine_after=1))
    a = mgr.register_tenant("a", 128)
    mgr.register_tenant("b", 128)
    a.module_load("bump", bump)
    part = mgr.bounds.lookup("a")
    a.launch_kernel("bump", args=(jnp.int32(part.end + 50), 4))
    mgr.run_queued()                         # poll quarantines "a"
    reg = mgr.telemetry.registry
    assert not mgr.quarantine.state_of("a").admissible
    assert reg.counter("quarantines", tenant="a") == 1
    assert reg.gauge("violations_gather", tenant="a") >= 1
    names = {(e.name, e.track) for e in mgr.telemetry.trace.events()}
    assert ("quarantine", "a") in names
    assert mgr.metrics_report()["tenants"]["a"]["state"] != "active"


# ---------------------------------------------------------------------------
# Serving plane: jit/eager bit-identity + request counters
# ---------------------------------------------------------------------------


def _serve_run(jit_steps):
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=4, max_len=16, jit_steps=jit_steps)
    eng.register_tenant("t0", 2)
    eng.register_tenant("t1", 2)
    rng = np.random.default_rng(0)
    for t in ("t0", "t1"):
        eng.submit(t, rng.integers(0, cfg.vocab, 8).astype(np.int32))
    outs = eng.run(max_new_tokens=3)
    return outs, eng.manager.telemetry


def test_serve_metrics_bit_identical_jit_vs_eager():
    """The compiled and eager trusted-step paths must agree on every
    logical metric (wall-clock histograms excluded) AND on the tokens —
    telemetry must not observe the implementation, only the schedule."""
    outs_j, tel_j = _serve_run(True)
    outs_e, tel_e = _serve_run(False)
    assert outs_j == outs_e
    snap_j = tel_j.registry.snapshot(include_timing=False)
    snap_e = tel_e.registry.snapshot(include_timing=False)
    assert snap_j == snap_e
    assert tel_j.registry.counter("requests", tenant="t0") == 1
    assert tel_j.registry.counter("requests", tenant="t1") == 1


def test_shared_manager_refuses_per_engine_telemetry_override():
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine, make_shared_manager

    cfg = get_config("stablelm-3b").reduced()
    mgr = make_shared_manager(2, max_batch=2)
    with pytest.raises(ValueError, match="telemetry"):
        ServeEngine(cfg, max_batch=2, manager=mgr, telemetry=False)


# ---------------------------------------------------------------------------
# Dashboard rendering
# ---------------------------------------------------------------------------


def test_sparkline():
    assert sparkline([]) == ""
    assert sparkline([0, 0, 0]) == "▁▁▁"
    s = sparkline([0, 1, 2, 4])
    assert len(s) == 4 and s[-1] == "█" and s[0] == "▁"
    assert sparkline([5]) == "█"


def test_format_report_renders_live_manager():
    mgr, clients, ptrs = make_mgr(2)
    drive(mgr, clients, ptrs, rounds=2)
    text = format_report(mgr.metrics_report(),
                         registry=mgr.telemetry.registry)
    assert "guardian flight recorder" in text
    for section in ("tenants", "scheduler", "drain cycles", "jit cache",
                    "elastic", "memory", "launch path", "slo ledger",
                    "trace"):
        assert section in text
    assert "t0" in text and "t1" in text
    assert "▁" in text or "█" in text        # bucket sparklines present


def test_format_report_tolerates_empty_report():
    text = format_report({})
    assert "guardian flight recorder" in text
    assert "0 tenant(s)" in text
