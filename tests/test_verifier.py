"""Static bounds verifier tests (core/verifier.py) — abstract-domain
transfer functions, loop-carry widening, the PROVEN/FENCED/REFUTED
contract, fence elision end-to-end, and the manager/scheduler wiring.

The hypothesis mirrors assert the verifier's two soundness directions:
PROVEN sites are never refuted at runtime (elided and fenced builds are
bit-identical for every launch), and REFUTED sites always trip the
runtime CHECK counter when forced through with ``verify=False``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.fence import FenceParams, FencePolicy
from repro.core.sandbox import sandbox, sandbox_report
from repro.core.verifier import (
    FENCED,
    PROVEN,
    REFUTED,
    GuardianStaticViolation,
    verify,
)


def _params(base=64, size=64):
    return FenceParams(base=base, size=size)


ARENA = jnp.arange(256.0)


# ---------------------------------------------------------------------------
# Classification sweep — one kernel per abstract-domain feature
# ---------------------------------------------------------------------------

def _fence_aware(arena, base, mask, ptr):
    idx = (ptr + jnp.arange(8, dtype=jnp.int32))
    return arena, jnp.take(arena, (idx & mask) | base, axis=0)


def _clamped(arena, ptr):
    idx = jnp.clip(ptr, 64, 120) + jnp.arange(4, dtype=jnp.int32)
    return arena, jnp.take(arena, idx, axis=0)


def _rem_carry_scan(arena, ptr):
    # (ptr & 63) not rem: truncated rem of a negative pointer is negative,
    # which the verifier correctly refuses to prove
    def body(carry, _):
        nxt = 64 + jax.lax.rem(carry + 1, jnp.int32(64))
        return nxt, jnp.take(arena, carry, axis=0)
    _, ys = jax.lax.scan(body, 64 + (ptr & 63), None, length=4)
    return arena, ys


def _raw_pointer(arena, ptr):
    return arena, jnp.take(arena, ptr + jnp.arange(4, dtype=jnp.int32),
                           axis=0)


def _static_oob(arena, x):
    idx = jnp.arange(4, dtype=jnp.int32) - 10_000_000
    return arena, jnp.take(arena, idx, axis=0) + x


def test_fence_aware_kernel_fully_proven_symbolically():
    """A kernel applying its own (idx & mask) | base fence proves itself
    row-exact against the *symbolic* (B, S) pair — any partition."""
    proof = verify(_fence_aware,
                   (ARENA, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
                   arena_argnums=(0,), bound_argnums=(1, 2))
    assert proof.symbolic and proof.fully_proven
    assert [s.verdict for s in proof.sites] == [PROVEN]


def test_clamp_proven_against_static_row():
    proof = verify(_clamped, (ARENA, jnp.int32(0)),
                   params=_params())
    assert [s.verdict for s in proof.sites] == [PROVEN]
    assert not proof.symbolic      # holds only for this (base, size)


def test_scan_carry_widening_converges_and_proves():
    """rem-bounded loop carry: widening + the rem transfer keep the
    carried index inside [64, 127] at fixpoint."""
    proof = verify(_rem_carry_scan, (ARENA, jnp.int32(0)),
                   params=_params())
    assert [s.verdict for s in proof.sites] == [PROVEN]


def test_raw_pointer_stays_fenced():
    proof = verify(_raw_pointer, (ARENA, jnp.int32(0)), params=_params())
    assert [s.verdict for s in proof.sites] == [FENCED]


def test_static_oob_refuted_with_site_diagnostic():
    proof = verify(_static_oob, (ARENA, jnp.float32(0.0)),
                   params=_params())
    assert [s.verdict for s in proof.sites] == [REFUTED]
    assert proof.refuted_sites()[0].kind.name == "GATHER"


def test_refuted_kernel_raises_at_trace_time():
    sb = sandbox(_static_oob, arena_argnums=(0,), verify=True)
    with pytest.raises(GuardianStaticViolation) as ei:
        sb(_params(), ARENA, jnp.float32(0.0))
    assert "provably out-of-bounds" in str(ei.value)
    assert "gather" in str(ei.value)     # the site-level diagnostic


def test_extent_mode_admits_guardspec_partitions():
    """Extent mode: a static FenceParams found in the operands declares
    an admissible partition for accesses that exceed no extent."""
    def step(arena, idx, fp):
        fenced = (idx & (fp.size - 1)) | fp.base
        return arena, jnp.take(arena, fenced, axis=0)

    proof = verify(step, (ARENA, jnp.int32(999),
                          FenceParams(base=128, size=64)), mode="extent")
    assert proof.fully_proven and proof.mode == "extent"


# ---------------------------------------------------------------------------
# PROVEN ⇒ fence elision is invisible (bit-identical, never refuted)
# ---------------------------------------------------------------------------

def _run_both(kernel, fp, args, bound=()):
    """(elided_output, fenced_output) for one kernel + launch."""
    elided = sandbox(kernel, arena_argnums=(0,), verify=True,
                     bound_argnums=bound)
    fenced = sandbox(kernel, arena_argnums=(0,), verify=False,
                     bound_argnums=bound)
    (_, out_e), _ = elided(fp, ARENA, *args)
    (_, out_f), _ = fenced(fp, ARENA, *args)
    return np.asarray(out_e), np.asarray(out_f)


def test_proven_sites_elide_bit_identical_sweep():
    """Deterministic sweep: every in-partition launch of a proven kernel
    is bit-identical with fences elided vs kept."""
    fp = _params()
    for ptr in range(0, 256, 7):
        base, mask = jnp.int32(fp.base), jnp.int32(fp.mask)
        out_e, out_f = _run_both(_fence_aware, fp, (base, mask,
                                                    jnp.int32(ptr)),
                                 bound=(1, 2))
        np.testing.assert_array_equal(out_e, out_f)
    for ptr in range(-8, 300, 31):
        out_e, out_f = _run_both(_clamped, fp, (jnp.int32(ptr),))
        np.testing.assert_array_equal(out_e, out_f)
        out_e, out_f = _run_both(_rem_carry_scan, fp, (jnp.int32(ptr),))
        np.testing.assert_array_equal(out_e, out_f)


def test_elision_actually_removes_fences():
    rep = sandbox_report(_clamped, (ARENA, jnp.int32(0)), verify=True,
                         params=_params())
    assert rep.elided_total == 1 and rep.fenced_total == 0
    rep = sandbox_report(_clamped, (ARENA, jnp.int32(0)), verify=False,
                         params=_params())
    assert rep.elided_total == 0 and rep.fenced_total == 1


def test_refuted_site_trips_check_counter_when_forced_through():
    """verify=False forces the refuted kernel through: the runtime CHECK
    fence must catch exactly what the verifier predicted."""
    sb = sandbox(_static_oob, arena_argnums=(0,),
                 policy=FencePolicy.CHECK, count_violations=True,
                 verify=False)
    (_, _), ok, counts = sb(_params(), ARENA, jnp.float32(0.0))
    assert not bool(ok)
    # all 4 lanes of the refuted gather are out of bounds
    assert int(np.asarray(counts)[0]) == 4


@given(ptr=st.integers(min_value=-(2 ** 20), max_value=2 ** 20))
@settings(max_examples=50, deadline=None)
def test_hyp_proven_never_refuted_at_runtime(ptr):
    """Property mirror of the sweep: for ANY launch operand the elided
    and fenced builds of a PROVEN kernel agree bit-for-bit (a PROVEN
    site can never be a runtime violation)."""
    fp = _params()
    out_e, out_f = _run_both(_clamped, fp, (jnp.int32(ptr),))
    np.testing.assert_array_equal(out_e, out_f)
    out_e, out_f = _run_both(_rem_carry_scan, fp, (jnp.int32(ptr),))
    np.testing.assert_array_equal(out_e, out_f)


@given(shift=st.integers(min_value=256, max_value=2 ** 24))
@settings(max_examples=25, deadline=None)
def test_hyp_refuted_always_trips_check(shift):
    """Any always-OOB constant offset: REFUTED statically, and the CHECK
    counter fires on every forced launch."""
    def kernel(arena, x):
        idx = jnp.arange(4, dtype=jnp.int32) + shift
        return arena, jnp.take(arena, idx, axis=0) + x

    proof = verify(kernel, (ARENA, jnp.float32(0.0)), params=_params())
    assert [s.verdict for s in proof.sites] == [REFUTED]
    sb = sandbox(kernel, arena_argnums=(0,), policy=FencePolicy.CHECK,
                 count_violations=True, verify=False)
    (_, _), ok, counts = sb(_params(), ARENA, jnp.float32(0.0))
    assert not bool(ok) and int(np.asarray(counts)[0]) == 4


# ---------------------------------------------------------------------------
# Manager + scheduler wiring
# ---------------------------------------------------------------------------

def _manager(policy, slots=2048):
    from repro.core.manager import GuardianManager
    return GuardianManager(total_slots=slots, policy=policy,
                           standalone_fast_path=False)


def _launch(mgr, tenant, name, *args):
    req = mgr.launch_kernel(tenant, name, args=args)
    mgr.synchronize()
    return req.result


def test_manager_sandbox_report_api():
    mgr = _manager(FencePolicy.BITWISE)
    mgr.register_tenant("t1", 256)
    mgr.register_tenant("t2", 256)
    mgr.register_kernel("fa", _fence_aware, fence_aware=True)
    mgr.register_kernel("raw", _raw_pointer)
    proof = mgr.sandbox_report("fa", example_args=(jnp.int32(0),))
    assert proof.symbolic and proof.fully_proven
    proof = mgr.sandbox_report("raw", example_args=(jnp.int32(0),))
    assert proof.n_fenced == 1 and not proof.fully_proven


def test_manager_fence_aware_kernel_all_policies():
    """The manager forwards the row scalars into a fence-aware kernel on
    every policy path; outputs match the raw-kernel result in-partition."""
    for pol in (FencePolicy.BITWISE, FencePolicy.CHECK,
                FencePolicy.MODULO):
        mgr = _manager(pol)
        c1 = mgr.register_tenant("t1", 256)
        mgr.register_tenant("t2", 256)
        mgr.register_kernel("fa", _fence_aware, fence_aware=True)
        p = mgr.malloc("t1", 16)
        c1.memcpy_h2d(p, np.arange(16.0))
        mgr.synchronize()
        out = _launch(mgr, "t1", "fa", jnp.int32(p.addr))
        np.testing.assert_array_equal(np.asarray(out)[:8],
                                      np.arange(8.0))


def test_scheduler_routes_proven_check_batches_to_fused_path():
    """A fully-proven symbolic kernel under CHECK policy rides the plain
    fused path (proven_steps), skipping the ViolationLog plumbing; an
    unprovable kernel keeps the CHECK commit path (check_steps)."""
    mgr = _manager(FencePolicy.CHECK)
    c1 = mgr.register_tenant("t1", 256)
    c2 = mgr.register_tenant("t2", 256)
    mgr.register_kernel("fa", _fence_aware, fence_aware=True)
    mgr.register_kernel("raw", _raw_pointer)
    p1, p2 = mgr.malloc("t1", 16), mgr.malloc("t2", 16)
    c1.memcpy_h2d(p1, np.arange(16.0))
    c2.memcpy_h2d(p2, np.arange(100.0, 116.0))
    mgr.synchronize()

    r1 = mgr.launch_kernel("t1", "fa", args=(jnp.int32(p1.addr),))
    r2 = mgr.launch_kernel("t2", "fa", args=(jnp.int32(p2.addr),))
    mgr.synchronize()
    np.testing.assert_array_equal(np.asarray(r1.result)[:4],
                                  np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(r2.result)[:4],
                                  np.arange(100.0, 104.0))
    assert mgr.scheduler.stats.proven_steps == 1
    assert mgr.scheduler.stats.check_steps == 0

    mgr.launch_kernel("t1", "raw", args=(jnp.int32(p1.addr),))
    mgr.launch_kernel("t2", "raw", args=(jnp.int32(p2.addr),))
    mgr.synchronize()
    assert mgr.scheduler.stats.proven_steps == 1
    assert mgr.scheduler.stats.check_steps == 1
    assert "proven_steps" in mgr.scheduler.stats.summary()


def test_trusted_verify_demands_full_proof():
    from repro.core.manager import GuardianManager

    def good_step(arena, x):
        idx = jnp.arange(8, dtype=jnp.int32) & jnp.int32(63)
        return arena, jnp.take(arena, idx, axis=0) + x

    def bad_step(arena, ptr):
        return arena, jnp.take(arena,
                               ptr + jnp.arange(4, dtype=jnp.int32),
                               axis=0)

    mgr = GuardianManager(total_slots=1024)
    mgr.register_trusted_kernel("good", good_step, verify=True)
    mgr.register_trusted_kernel("bad", bad_step, verify=True)
    mgr.register_tenant("t1", 256)
    out = _launch(mgr, "t1", "good", jnp.float32(1.0))
    assert np.asarray(out).shape == (8,)
    with pytest.raises(GuardianStaticViolation):
        mgr.launch_kernel("t1", "bad", args=(jnp.int32(0),))
        mgr.synchronize()


def test_manager_refutes_oob_kernel_at_trace_time():
    mgr = _manager(FencePolicy.BITWISE)
    c1 = mgr.register_tenant("t1", 256)
    mgr.register_tenant("t2", 256)
    mgr.register_kernel("oob", _static_oob)
    with pytest.raises(GuardianStaticViolation):
        mgr.launch_kernel("t1", "oob", args=(jnp.float32(0.0),))
        mgr.synchronize()


def test_trusted_step_bundle_threads_verify():
    from repro.launch.steps import TrustedStepBundle

    def step(arena, pool, x):
        return arena, pool, x

    from repro.core.manager import GuardianManager
    mgr = GuardianManager(total_slots=512)
    bundle = TrustedStepBundle(
        pool_name="p", prefill_name="pf", decode_name="dc",
        prefill_fn=step, decode_fn=step, verify=True)
    bundle.register(mgr, {"buf": jnp.zeros((4, 4))})
    assert mgr.pointer_to_symbol["pf"].verify
    assert mgr.pointer_to_symbol["dc"].verify


# ---------------------------------------------------------------------------
# Lint CLI
# ---------------------------------------------------------------------------

def test_lint_kernel_audits_fully_proven():
    """The committed contract: the fenced gather/scatter/paged-attention
    kernels audit fully proven with their fences elided (ISSUE 6)."""
    from repro.lint import run_audits
    summaries, errors = run_audits(only="kernels.")
    assert not errors
    for name in ("kernels.gather_rows", "kernels.scatter_pages",
                 "kernels.paged_attention"):
        assert summaries[name]["fully_proven"], summaries[name]
        assert summaries[name]["sites"] >= 1
