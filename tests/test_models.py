"""Per-arch smoke tests (reduced configs) + decode/forward consistency +
SSD chunked-vs-sequential equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import get_model
from repro.models import transformer as TF
from repro.models.ssd import ssd_chunked, ssd_reference

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, rng=RNG):
    if cfg.family == "encdec":
        return {"src": jnp.ones((B, 16, cfg.d_model), jnp.float32),
                "tgt": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"tokens": jax.random.randint(rng, (B, S + 1), 0,
                                             cfg.vocab),
                "patches": jnp.ones((B, 8, cfg.d_model), jnp.float32),
                "positions": jnp.tile(
                    jnp.arange(S, dtype=jnp.int32)[None, :, None],
                    (B, 1, 3))}
    return {"tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step on CPU, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, batch, remat=False))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_serve_step(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B, S = 2, 32
    if cfg.family == "ssm":
        cache = api.init_cache(B)
    elif cfg.family == "encdec":
        cache = api.init_cache(B, 64, src_len=16)
    else:
        cache = api.init_cache(B, 64)
    batch = make_batch(cfg, B, S)
    batch.pop("positions", None)
    if "tokens" in batch:
        batch["tokens"] = batch["tokens"][:, :S]
    if "tgt" in batch:
        batch["tgt"] = batch["tgt"][:, :S]
    if cfg.family == "vlm":
        batch["positions"] = jnp.tile(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (2, 1, 3))
    cache, logits = api.prefill(params, cache, batch)
    assert logits.shape == (B, cfg.vocab)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    cache, logits = api.decode(params, cache, nxt)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ["llama3-405b", "qwen1.5-32b",
                                  "grok-1-314b"])
def test_decode_matches_forward(arch):
    """prefill + decode == full forward, position by position (f32)."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B, S = 2, 48
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    if cfg.family == "moe":
        from repro.models import moe as MOE
        full, _ = MOE.forward(cfg, params, toks)
    else:
        full = TF.forward(cfg, params, toks)
    cache = api.init_cache(B, 128, dtype=jnp.float32)
    cache, lg = api.prefill(params, cache, {"tokens": toks[:, :S - 3]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, S - 4]), atol=2e-4)
    for t in range(S - 3, S):
        cache, lg = api.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]), atol=2e-4)


def test_hybrid_decode_matches_forward():
    cfg = get_config("zamba2-7b").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    from repro.models import hybrid as HY
    B, S = 2, 40
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    full = HY.forward(cfg, params, toks)
    cache = api.init_cache(B, 64, dtype=jnp.float32)
    cache, lg = api.prefill(params, cache, {"tokens": toks[:, :S - 2]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, S - 3]), atol=3e-4)
    for t in range(S - 2, S):
        cache, lg = api.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]), atol=3e-4)


def test_xlstm_decode_matches_forward():
    cfg = get_config("xlstm-350m").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    from repro.models import xlstm as XL
    B, S = 2, 24
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    full = XL.forward(cfg, params, toks)
    cache = api.init_cache(B)
    cache, lg = api.prefill(params, cache, {"tokens": toks[:, :S - 2]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, S - 3]), atol=3e-4)
    for t in range(S - 2, S):
        cache, lg = api.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]), atol=3e-4)


@pytest.mark.parametrize("S,chunk", [(16, 4), (37, 8), (64, 64),
                                     (100, 16)])
def test_ssd_chunked_matches_reference(S, chunk):
    rng = jax.random.PRNGKey(S)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    B, H, P, N = 2, 3, 8, 5
    u = jax.random.normal(k1, (B, S, H, P))
    a = -jnp.abs(jax.random.normal(k2, (B, S, H))) * 0.2
    b = jax.random.normal(k3, (B, S, H, N))
    c = jax.random.normal(k4, (B, S, H, N))
    h0 = jax.random.normal(rng, (B, H, N, P)) * 0.1
    y1, hf1 = ssd_chunked(u, a, b, c, h0=h0, chunk=chunk)
    y2, hf2 = ssd_reference(u, a, b, c, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2),
                               atol=2e-4)


def test_guarded_model_isolation():
    """Tenant guard: adversarial slot ids in the cache wrap into the
    tenant's own slot partition — neighbour slots never written."""
    from repro.core.fence import FenceParams, FencePolicy
    from repro.models.guard import GuardSpec
    cfg = get_config("llama3-405b").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B = 2
    cache = api.init_cache(2, 64, dtype=jnp.float32, slots=8)
    # tenant owns slots [0, 2); forge slot ids pointing at slot 5
    cache = dataclasses.replace(
        cache, slot_ids=jnp.asarray([5, 6], jnp.int32))
    guard = GuardSpec(policy=FencePolicy.BITWISE,
                      kv=FenceParams(base=0, size=2),
                      page=FenceParams(base=0, size=1),
                      vocab=FenceParams(base=0, size=256))
    toks = jax.random.randint(RNG, (B, 32), 0, cfg.vocab)
    cache2, _ = api.prefill(params, cache, {"tokens": toks}, guard=guard)
    # slots >= 2 remain untouched (all zeros)
    assert (np.asarray(cache2.k[:, 2:]) == 0).all()
    assert (np.asarray(cache2.k[:, :2]) != 0).any()


def test_shape_applicability_rules():
    assert not shape_applicable(get_config("llama3-405b"),
                                SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("zamba2-7b"),
                            SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("xlstm-350m"),
                            SHAPES["long_500k"])[0]
    for arch in list_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(arch), SHAPES[s])[0]


def test_param_counts_sane():
    # analytic counts should be within ~25% of the published sizes
    expect = {"llama3-405b": 405e9, "qwen1.5-32b": 32e9,
              "minicpm-2b": 2.4e9, "stablelm-3b": 2.8e9,
              "grok-1-314b": 314e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_encdec_decode_matches_train_forward():
    """seamless: prefill + decode logits == teacher-forced decoder logits."""
    from repro.models import encdec as ED
    cfg = get_config("seamless-m4t-medium").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B, S_src, S = 2, 16, 32
    src = jax.random.normal(RNG, (B, S_src, cfg.d_model))
    tgt = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    memory = ED.encode(cfg, params, src)
    full = ED.decode_train(cfg, params, tgt, memory)
    cache = api.init_cache(B, 64, src_len=S_src, dtype=jnp.float32)
    cache, lg = api.prefill(params, cache,
                            {"src": src, "tgt": tgt[:, :S - 2]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 3]),
                               atol=3e-4)
    for t in range(S - 2, S):
        cache, lg = api.decode(params, cache, tgt[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]), atol=3e-4)


def test_vlm_decode_matches_forward():
    """qwen2-vl: patched prefill + text decode == full M-RoPE forward."""
    from repro.models import vlm as VLM
    cfg = get_config("qwen2-vl-2b").reduced()
    api = get_model(cfg)
    params = api.init(RNG)
    B, S, NP = 2, 40, 8
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    patches = jax.random.normal(RNG, (B, NP, cfg.d_model)) * 0.02
    pos3 = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                    (B, 1, 3))
    full = VLM.forward(cfg, params, toks, patches, pos3)
    cache = api.init_cache(B, 64, dtype=jnp.float32)
    Sp = S - 2
    cache, lg = api.prefill(params, cache,
                            {"tokens": toks[:, :Sp],
                             "patches": patches,
                             "positions": pos3[:, :Sp]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp - 1]),
                               atol=3e-4)
    for t in range(Sp, S):
        cache, lg = api.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]), atol=3e-4)
