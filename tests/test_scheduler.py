"""Batched multi-tenant launch scheduler (Guardian §4.2.3–§4.2.4 at scale):
cross-tenant isolation of fused batches, coalescing fairness/ordering,
standalone fast path, equivalence with the per-launch drain, cross-cycle
lookahead under a latency budget, weighted fairness, and the LRU-bounded
jit caches."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (
    FencePolicy,
    GuardianManager,
    GuardianViolation,
    LaunchRequest,
    LRUCache,
    SharingMode,
)


def bump(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def evil_write(arena, target, n):
    idx = target + jnp.arange(n, dtype=jnp.int32)
    return arena.at[idx].set(999.0), None


def make_manager(n=4, slots=512, **kw):
    mgr = GuardianManager(total_slots=slots, **kw)
    clients = [mgr.register_tenant(f"t{i}", slots // (2 * n))
               for i in range(n)]
    return mgr, clients


# ---------------------------------------------------------------------------
# Fusion mechanics + equivalence
# ---------------------------------------------------------------------------


def test_compatible_launches_fuse_into_one_step():
    mgr, clients = make_manager(4)
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        ptrs.append(p)
    for _ in range(3):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    st = mgr.scheduler.stats
    assert st.batched_launches == 12
    assert st.fused_steps == 3
    assert list(st.batch_widths) == [4, 4, 4]
    assert st.mean_batch_width == 4.0 and st.max_batch_width == 4
    for c, p in zip(clients, ptrs):
        np.testing.assert_array_equal(c.memcpy_d2h(p, 8),
                                      np.full(8, 3.0, np.float32))


def test_fused_matches_per_launch_drain():
    """The fused path is bit-identical to batch_launches=False round-robin."""
    arenas = []
    for batched in (True, False):
        mgr, clients = make_manager(4, batch_launches=batched)
        for i, c in enumerate(clients):
            c.module_load("bump", bump)
            p = c.malloc(16)
            c.memcpy_h2d(p, np.arange(16, dtype=np.float32) * (i + 1))
            for _ in range(i + 1):           # unequal load per tenant
                c.launch_kernel("bump", ptrs=[p], args=(16,))
        mgr.synchronize()
        if batched:
            assert mgr.scheduler.stats.fused_steps > 0
        else:
            assert mgr.scheduler.stats.fused_steps == 0
        arenas.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(arenas[0], arenas[1])


# ---------------------------------------------------------------------------
# Cross-tenant isolation of every fused batch
# ---------------------------------------------------------------------------


def test_fused_batch_cross_tenant_isolation():
    """Every row of a fused batch is fenced with its own (base, mask): four
    tenants each aim a forged slot id straight at their neighbour, all four
    launches fuse into ONE device step, and every write wraps back into the
    attacker's own partition."""
    mgr, clients = make_manager(4, policy=FencePolicy.BITWISE)
    parts = [mgr.bounds.lookup(f"t{i}") for i in range(4)]
    # pre-fill every partition with a sentinel via validated transfers
    ptrs = []
    for i, c in enumerate(clients):
        c.module_load("evil", evil_write)
        p = c.malloc(16)
        c.memcpy_h2d(p, np.full(16, float(i + 1), np.float32))
        ptrs.append(p)
    mgr.synchronize()
    before = np.asarray(mgr.arena.buf).copy()

    # tenant i attacks tenant (i+1) % 4
    for i, c in enumerate(clients):
        victim = ptrs[(i + 1) % 4]
        c.launch_kernel("evil", args=(jnp.int32(victim.addr), 16))
    mgr.synchronize()
    assert list(mgr.scheduler.stats.batch_widths) == [4]   # one fused step

    after = np.asarray(mgr.arena.buf)
    for i, part in enumerate(parts):
        own = after[part.base:part.base + part.size]
        # the attacker's damage landed inside its OWN partition...
        assert (own == 999.0).any(), f"t{i}: wrap-around missing"
        # ...and its malloc'd sentinel region was never hit by a neighbour:
        # only values 999 (own wrapped write) or the original sentinel occur
        changed = own != before[part.base:part.base + part.size]
        assert (own[changed] == 999.0).all(), f"t{i}: foreign write leaked"


def test_fused_batch_isolation_matches_sequential_wraparound():
    """The wrap-around targets of a fused step equal the per-launch path's
    (same fence, same rows) — fusion changes scheduling, not semantics."""
    results = []
    for batched in (True, False):
        mgr, clients = make_manager(2, batch_launches=batched)
        for c in clients:
            c.module_load("evil", evil_write)
        other = mgr.bounds.lookup("t1")
        clients[0].launch_kernel(
            "evil", args=(jnp.int32(other.base + 3), 8))
        t0 = mgr.bounds.lookup("t0")
        clients[1].launch_kernel(
            "evil", args=(jnp.int32(t0.base + 5), 8))
        mgr.synchronize()
        results.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(results[0], results[1])


# ---------------------------------------------------------------------------
# Fairness + ordering of the coalescing drain
# ---------------------------------------------------------------------------


def test_coalescing_preserves_round_robin_fairness():
    """Unequal queue depths: each drain cycle takes at most one launch per
    tenant (round-robin selection), so light tenants finish early and the
    heavy tenant never monopolizes a batch."""
    mgr, clients = make_manager(3)
    for c in clients:
        c.module_load("bump", bump)
    ptrs = [c.malloc(4) for c in clients]
    for c, p in zip(clients, ptrs):
        c.memcpy_h2d(p, np.zeros(4, np.float32))
    mgr.synchronize()
    mgr.scheduler.dispatch_log.clear()
    loads = {0: 4, 1: 2, 2: 1}
    for i, c in enumerate(clients):
        for _ in range(loads[i]):
            c.launch_kernel("bump", ptrs=[ptrs[i]], args=(4,))
    mgr.synchronize()
    log = list(mgr.scheduler.dispatch_log)
    # cycle batches: (t0,t1,t2), (t0,t1), (t0,), (t0,)
    assert log == [("t0", "t1", "t2"), ("t0", "t1"), ("t0",), ("t0",)]
    for batch in log:
        assert len(set(batch)) == len(batch)   # ≤ 1 launch/tenant/batch


def test_head_of_line_blocking_preserves_tenant_order():
    """_take_batch never lets a tenant's later op jump its earlier one:
    once an op of tenant A is deferred, subsequent A-ops are blocked from
    the open batch even if compatible."""
    mgr, _ = make_manager(2)

    def req(tenant, name, static=7):
        return LaunchRequest(tenant_id=tenant, name=name,
                             policy=FencePolicy.BITWISE, entry=None,
                             part=None, call_args=(static,))

    sched = mgr.scheduler
    pending = [req("b", "k1"), req("a", "k2"), req("a", "k1")]
    batch, rest = sched._take_batch(pending)
    # a.k1 is compatible with the open k1 batch, but a.k2 was deferred
    # first — admitting a.k1 would reorder tenant a's program.
    assert [(r.tenant_id, r.name) for r in batch] == [("b", "k1")]
    assert [(r.tenant_id, r.name) for r in rest] == [("a", "k2"),
                                                     ("a", "k1")]


def test_incompatible_signatures_do_not_fuse():
    """Different kernels or different static launch dims -> separate
    device steps."""
    mgr, clients = make_manager(2)
    for c in clients:
        c.module_load("bump", bump)
    p0, p1 = clients[0].malloc(8), clients[1].malloc(8)
    clients[0].memcpy_h2d(p0, np.zeros(8, np.float32))
    clients[1].memcpy_h2d(p1, np.zeros(8, np.float32))
    mgr.synchronize()
    # same kernel, different static n -> incompatible
    clients[0].launch_kernel("bump", ptrs=[p0], args=(8,))
    clients[1].launch_kernel("bump", ptrs=[p1], args=(4,))
    mgr.synchronize()
    st = mgr.scheduler.stats
    assert st.fused_steps == 0 and st.single_steps == 2


def test_max_fuse_caps_batch_width():
    mgr = GuardianManager(total_slots=1024, max_fuse=2)
    clients = [mgr.register_tenant(f"t{i}", 64) for i in range(5)]
    for c in clients:
        c.module_load("bump", bump)
    ptrs = []
    for c in clients:
        p = c.malloc(4)
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        ptrs.append(p)
    mgr.synchronize()
    for c, p in zip(clients, ptrs):
        c.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    assert max(mgr.scheduler.stats.batch_widths) <= 2
    assert mgr.scheduler.stats.batched_launches + \
        mgr.scheduler.stats.single_steps == 5


# ---------------------------------------------------------------------------
# Standalone fast path + policy degradation
# ---------------------------------------------------------------------------


def test_standalone_single_tenant_native_fast_path():
    """Paper §4.2.3: a standalone tenant gets the NATIVE kernel — the
    scheduler never builds a fused step for it."""
    mgr = GuardianManager(total_slots=256)
    c = mgr.register_tenant("solo", 64)
    c.module_load("bump", bump)
    p = c.malloc(8)
    c.memcpy_h2d(p, np.zeros(8, np.float32))
    for _ in range(4):
        c.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    st = mgr.scheduler.stats
    assert st.fused_steps == 0
    assert st.single_steps == 4
    # the enqueued requests carried the NONE (native) policy
    assert all(len(b) == 1 for b in mgr.scheduler.dispatch_log)
    np.testing.assert_array_equal(c.memcpy_d2h(p, 8),
                                  np.full(8, 4.0, np.float32))


def test_stale_standalone_policy_reresolved_at_drain():
    """A launch enqueued while standalone (NONE/native) must NOT execute
    unfenced after a second tenant registers: the policy is re-resolved
    when the op is selected, so the deferred flush runs the fenced twin
    and the attack wraps into the attacker's own partition."""
    mgr = GuardianManager(total_slots=256)
    a = mgr.register_tenant("a", 64)
    a.module_load("evil", evil_write)
    # enqueued while standalone -> snapshotted as NONE (native)
    a.launch_kernel("evil", args=(jnp.int32(64 + 3), 8))
    # second tenant registers and uploads a secret BEFORE the drain
    b = mgr.register_tenant("b", 64)
    pb = b.malloc(16)
    b.memcpy_h2d(pb, np.full(16, 7.0, np.float32))
    mgr.synchronize()
    part_b = mgr.bounds.lookup("b")
    sl = np.asarray(mgr.arena.unsafe_read_range(part_b.base, part_b.size))
    assert not (sl == 999.0).any(), "stale native launch hit tenant b"
    part_a = mgr.bounds.lookup("a")
    own = np.asarray(mgr.arena.unsafe_read_range(part_a.base, part_a.size))
    assert (own == 999.0).any()       # fenced wrap into a's own partition


def test_manager_fence_table_tracks_repartition():
    """Remove + re-register under the same tenant name must rebuild the
    manager's all-tenant FenceTable (the partition bounds can move), magic
    rows included — the serving plane reads its per-row guard from here."""
    mgr = GuardianManager(total_slots=256)
    mgr.register_tenant("a", 16)
    t1, row1 = mgr.fence_table()
    old_row = np.asarray(t1.rows)[row1["a"]].copy()
    mgr.register_tenant("b", 32)      # occupies slots next to a
    mgr.remove_tenant("a")
    mgr.register_tenant("a", 64)      # buddy allocator must move a
    t2, row2 = mgr.fence_table()
    new_part = mgr.bounds.lookup("a")
    np.testing.assert_array_equal(
        np.asarray(t2.rows)[row2["a"]],
        [new_part.base, new_part.mask])
    assert not np.array_equal(old_row, np.asarray(t2.rows)[row2["a"]])
    # the magic table tracks the same rebuild: (base, size, m, s) with the
    # reciprocal constants of the NEW size (m is a uint32 bit pattern)
    from repro.core.fence import magic_row
    m, s = magic_row(new_part.size)
    np.testing.assert_array_equal(
        np.asarray(t2.magic).view(np.uint32)[row2["a"]],
        np.array([new_part.base, new_part.size, m, s], np.uint32))


# ---------------------------------------------------------------------------
# MODULO fusion via the magic row table
# ---------------------------------------------------------------------------


def test_modulo_launches_fuse_into_one_step():
    """MODULO is no longer the odd one out: compatible MODULO launches
    from different tenants coalesce into one fused device step driven by
    the (T, 4) magic row table."""
    mgr, clients = make_manager(4, policy=FencePolicy.MODULO)
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        ptrs.append(p)
    for _ in range(3):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    st = mgr.scheduler.stats
    assert st.fused_steps == 3 and st.mean_batch_width == 4.0
    for c, p in zip(clients, ptrs):
        np.testing.assert_array_equal(c.memcpy_d2h(p, 8),
                                      np.full(8, 3.0, np.float32))


def test_modulo_fused_matches_per_launch_drain():
    """Fused MODULO batches are byte-identical to standalone MODULO
    launches (the per-launch path's static per-partition magic constants
    vs the fused path's traced magic rows — same exact division).  Mirrors
    the CHECK selective-commit equality test."""
    arenas = []
    for batched in (True, False):
        mgr, clients = make_manager(4, policy=FencePolicy.MODULO,
                                    batch_launches=batched)
        for i, c in enumerate(clients):
            c.module_load("bump", bump)
            p = c.malloc(16)
            c.memcpy_h2d(p, np.arange(16, dtype=np.float32) * (i + 1))
            for _ in range(i + 1):           # unequal load per tenant
                c.launch_kernel("bump", ptrs=[p], args=(16,))
        mgr.synchronize()
        if batched:
            assert mgr.scheduler.stats.fused_steps > 0
        else:
            assert mgr.scheduler.stats.fused_steps == 0
        arenas.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(arenas[0], arenas[1])


def test_modulo_fused_batch_cross_tenant_isolation():
    """Fused MODULO rows wrap a forged pointer into the attacker's own
    partition — same containment as the static per-partition binaries."""
    mgr, clients = make_manager(4, policy=FencePolicy.MODULO)
    parts = [mgr.bounds.lookup(f"t{i}") for i in range(4)]
    ptrs = []
    for i, c in enumerate(clients):
        c.module_load("evil", evil_write)
        p = c.malloc(16)
        c.memcpy_h2d(p, np.full(16, float(i + 1), np.float32))
        ptrs.append(p)
    mgr.synchronize()
    before = np.asarray(mgr.arena.buf).copy()
    for i, c in enumerate(clients):
        victim = ptrs[(i + 1) % 4]
        c.launch_kernel("evil", args=(jnp.int32(victim.addr), 16))
    mgr.synchronize()
    assert list(mgr.scheduler.stats.batch_widths) == [4]
    after = np.asarray(mgr.arena.buf)
    for i, part in enumerate(parts):
        own = after[part.base:part.base + part.size]
        assert (own == 999.0).any(), f"t{i}: wrap-around missing"
        changed = own != before[part.base:part.base + part.size]
        assert (own[changed] == 999.0).all(), f"t{i}: foreign write leaked"


def test_modulo_fused_non_pow2_partition_sizes():
    """The magic row table handles arbitrary partition sizes: a fused
    MODULO step over hand-built non-pow2 bounds produces the same arena
    bytes as standalone static-magic launches over the same bounds (the
    reciprocal constants, not the pow2 mask, do the wrapping)."""
    from repro.core import FenceParams, FenceTable, sandbox

    bounds = [(0, 48), (48, 12), (60, 3)]        # none pow2-aligned
    table = FenceTable.modulo_from_bounds([b for b, _ in bounds],
                                          [s for _, s in bounds])
    assert table.rows is None and table.magic.shape == (3, 4)

    def kern(arena, start, n):
        idx = start + jnp.arange(n, dtype=jnp.int32)
        vals = jnp.take(arena, idx, axis=0)
        return arena.at[idx].set(vals + 100.0), None

    # standalone reference: static magic constants per partition
    ref = np.arange(64, dtype=np.float32)
    arena_ref = jnp.asarray(ref)
    sb = sandbox(kern, arena_argnums=(0,), policy=FencePolicy.MODULO)
    starts = [40, 55, 61]                        # each straddles its end
    for (base, size), start in zip(bounds, starts):
        (arena_ref, _), _ok = sb(FenceParams(base=base, size=size),
                                 arena_ref, jnp.int32(start), 8)

    # fused run: same launches as rows of one compiled step
    from repro.core.manager import GuardianManager
    mgr = GuardianManager(total_slots=64)
    mgr.register_kernel("kern", kern)
    entry = mgr.pointer_to_symbol["kern"]
    fused = mgr.scheduler._build_fused_modulo(
        entry, (("d", (), jnp.int32), ("s", 8)), 3)
    arena = jnp.asarray(ref)
    starts_dev = [jnp.int32(s) for s in starts]
    arena, _outs = fused(arena, table.magic, *starts_dev)
    np.testing.assert_array_equal(np.asarray(arena), np.asarray(arena_ref))


def test_per_tenant_policies_fuse_in_separate_batches():
    """Tenants may override the manager's fence policy; policy groups
    fuse separately (the policy is part of the batch signature) and the
    MODULO group rides the magic row table."""
    mgr = GuardianManager(total_slots=512, policy=FencePolicy.BITWISE)
    clients = [
        mgr.register_tenant("m0", 32, policy=FencePolicy.MODULO),
        mgr.register_tenant("m1", 32, policy=FencePolicy.MODULO),
        mgr.register_tenant("b0", 32),
        mgr.register_tenant("b1", 32),
    ]
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        ptrs.append(p)
    mgr.synchronize()
    mgr.scheduler.dispatch_log.clear()
    for c, p in zip(clients, ptrs):
        c.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    # one MODULO pair + one BITWISE pair, never mixed
    assert sorted(mgr.scheduler.dispatch_log) == [("b0", "b1"),
                                                  ("m0", "m1")]
    assert mgr.scheduler.stats.fused_steps == 2
    for c, p in zip(clients, ptrs):
        np.testing.assert_array_equal(c.memcpy_d2h(p, 8),
                                      np.full(8, 1.0, np.float32))


# ---------------------------------------------------------------------------
# Stats + shared fairness helper
# ---------------------------------------------------------------------------


def test_scheduler_stats_summary_fresh_is_all_zeros():
    """A fresh scheduler has dispatched nothing: every summary metric is
    0.0 — no division by zero for device_steps == 0 (regression)."""
    from repro.core import SchedulerStats

    st = SchedulerStats()
    summary = st.summary()
    assert summary == {k: 0.0 for k in summary}
    assert st.launches_per_step == 0.0
    assert st.fused_fraction == 0.0
    assert st.mean_batch_width == 0.0


def test_round_robin_interleave_matches_drain_fairness():
    from repro.core import round_robin_interleave

    by_tenant = {"t0": ["a0", "a1", "a2", "a3"], "t1": ["b0", "b1"],
                 "t2": ["c0"]}
    order = round_robin_interleave(by_tenant)
    assert order == ["a0", "b0", "c0", "a1", "b1", "a2", "a3"]
    assert round_robin_interleave(by_tenant, limit=4) == \
        ["a0", "b0", "c0", "a1"]
    assert round_robin_interleave({}) == []
    # inputs are not consumed
    assert by_tenant["t0"] == ["a0", "a1", "a2", "a3"]


def test_launch_result_handle_filled_by_drain():
    """SPATIAL launches return a request handle whose .result is set once
    the scheduler dispatches it — fused, CHECK and single paths alike."""
    for policy in (FencePolicy.BITWISE, FencePolicy.MODULO,
                   FencePolicy.CHECK):
        mgr, clients = make_manager(2, policy=policy)

        def echo(arena, ptr, n):
            idx = ptr + jnp.arange(n, dtype=jnp.int32)
            vals = jnp.take(arena, idx, axis=0)
            return arena.at[idx].set(vals + 1.0), jnp.sum(vals)

        reqs = []
        for i, c in enumerate(clients):
            c.module_load("echo", echo)
            p = c.malloc(4)
            c.memcpy_h2d(p, np.full(4, float(i + 1), np.float32))
            reqs.append(c.launch_kernel("echo", ptrs=[p], args=(4,)))
        mgr.synchronize()
        for i, req in enumerate(reqs):
            assert req.result is not None, policy
            assert float(req.result) == 4.0 * (i + 1), policy


def test_check_policy_contains_and_attributes_on_scheduler_path():
    """CHECK launches ride the scheduler's attributing commit path: the
    offender's writes are rolled back on device and the violation lands in
    its ViolationLog row — no exception interrupts the drain (the
    per-launch paths, TIME_SHARE and batch_launches=False, still raise;
    see test_manager.test_check_policy_detects_oob)."""
    mgr = GuardianManager(total_slots=256, policy=FencePolicy.CHECK)
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)

    def oob(arena, n):
        idx = 200 + jnp.arange(n, dtype=jnp.int32)   # b's partition
        return arena.at[idx].set(1.0), None

    a.module_load("oob", oob)
    a.launch_kernel("oob", args=(4,))
    mgr.synchronize()                     # contains; does not raise
    assert mgr.scheduler.stats.check_steps == 1
    assert not (np.asarray(mgr.arena.buf) == 1.0).any()   # rolled back
    assert mgr.violog.counts("a")["scatter"] == 4
    assert mgr.violog.total("b") == 0


def test_check_policy_unbatched_drain_still_raises():
    """batch_launches=False restores the raising per-launch CHECK path."""
    mgr = GuardianManager(total_slots=256, policy=FencePolicy.CHECK,
                          batch_launches=False)
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)

    def oob(arena, n):
        idx = 9999 + jnp.arange(n, dtype=jnp.int32)
        return arena.at[idx].set(1.0), None

    # verify=False: the constant-OOB scatter would be refuted at trace
    # time otherwise; this test pins the raising runtime CHECK path
    a.module_load("oob", oob, verify=False)
    a.launch_kernel("oob", args=(4,))
    with pytest.raises(GuardianViolation):
        mgr.synchronize()
    assert mgr.violations
    assert mgr.violog.counts("a")["scatter"] == 4   # attributed even so


# ---------------------------------------------------------------------------
# Cross-cycle lookahead + weighted fairness
# ---------------------------------------------------------------------------


def test_lookahead_off_is_the_default_and_changes_nothing():
    """lookahead_cycles=0 (default): every launch dispatches in its
    submission cycle — mean_queue_age and lookahead_fused stay 0."""
    mgr, clients = make_manager(3)
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(4)
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        ptrs.append(p)
    for _ in range(3):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    st_ = mgr.scheduler.stats
    assert st_.mean_queue_age == 0.0
    assert st_.lookahead_fused == 0
    assert all(a == 0 for a in st_.queue_ages)


def test_lookahead_fuses_across_cycles_exact_stats():
    """2 tenants x 2 compatible ops, lookahead=1: the first cycle's
    width-2 batch is held, the second cycle's ops join, and ONE width-4
    step dispatches — with exactly the two held launches counted as
    lookahead_fused and mean_queue_age = (1+1+0+0)/4."""
    mgr, clients = make_manager(2, lookahead_cycles=1)
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(4)
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        ptrs.append(p)
    mgr.synchronize()
    for _ in range(2):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    st_ = mgr.scheduler.stats
    assert st_.fused_steps == 1 and list(st_.batch_widths) == [4]
    assert st_.lookahead_fused == 2
    assert st_.queue_age_sum == 2 and st_.age_samples == 4
    assert st_.mean_queue_age == 0.5
    assert st_.summary()["lookahead_fused"] == 2.0
    assert st_.summary()["mean_queue_age"] == 0.5
    # every result handle filled (run_queued always fully drains)
    assert mgr.scheduler.pending == 0
    for c, p in zip(clients, ptrs):
        np.testing.assert_array_equal(c.memcpy_d2h(p, 4),
                                      np.full(4, 2.0, np.float32))


def test_lookahead_bit_identical_to_eager_drain():
    """Lookahead changes *when* fused steps dispatch, never what they
    compute: the final arena equals the no-lookahead (and the unbatched)
    drain over the same launches."""
    arenas = []
    for look, batched in ((3, True), (0, True), (0, False)):
        mgr, clients = make_manager(3, lookahead_cycles=look,
                                    batch_launches=batched)
        for i, c in enumerate(clients):
            c.module_load("bump", bump)
            p = c.malloc(8)
            c.memcpy_h2d(p, np.arange(8, dtype=np.float32) * (i + 1))
            for _ in range(i + 2):          # unequal load per tenant
                c.launch_kernel("bump", ptrs=[p], args=(8,))
        mgr.synchronize()
        arenas.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(arenas[0], arenas[1])
    np.testing.assert_array_equal(arenas[0], arenas[2])


def test_lookahead_latency_budget_bounds_queue_age():
    """Deterministic sweep: whatever the load pattern, no launch waits
    more than lookahead_cycles // weight drain cycles (the hold check
    runs every cycle; the end-of-drain flush executes unconditionally)."""
    for look in (1, 2, 3):
        for depths in ((5, 1, 2), (1, 1, 1), (4, 4, 0), (7, 2, 5)):
            mgr, clients = make_manager(3, lookahead_cycles=look,
                                        max_fuse=4)
            for c in clients:
                c.module_load("bump", bump)
            ptrs = [c.malloc(4) for c in clients]
            for c, p in zip(clients, ptrs):
                c.memcpy_h2d(p, np.zeros(4, np.float32))
            mgr.synchronize()
            mgr.scheduler.stats.queue_ages.clear()
            for c, p, d in zip(clients, ptrs, depths):
                for _ in range(d):
                    c.launch_kernel("bump", ptrs=[p], args=(4,))
            mgr.synchronize()
            ages = list(mgr.scheduler.stats.queue_ages)
            assert len(ages) == sum(depths)
            assert mgr.scheduler.pending == 0
            assert all(a <= look for a in ages), (look, depths, ages)


def test_weighted_priority_tenant_never_held_exact():
    """A weight-4 tenant (weight > lookahead) zeroes the hold budget of
    every batch its ops join: its launches always dispatch in their
    submission cycle while best-effort tenants still fuse via lookahead.
    Exact dispatch trace: [p,p,p,a,b] at age 0, then [a,a,b,b] with the
    held pair at age 1."""
    mgr = GuardianManager(total_slots=512, lookahead_cycles=3)
    prio = mgr.register_tenant("p", 32, weight=4)
    others = [mgr.register_tenant(t, 32) for t in ("a", "b")]
    clients = [prio, *others]
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(4)
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        ptrs.append(p)
    mgr.synchronize()
    mgr.scheduler.dispatch_log.clear()
    mgr.scheduler.stats.queue_ages.clear()
    for c, p in zip(clients, ptrs):
        for _ in range(3):
            c.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    log = list(mgr.scheduler.dispatch_log)
    assert log == [("p", "p", "p", "a", "b"), ("a", "b", "a", "b")]
    assert list(mgr.scheduler.stats.queue_ages) == [0, 0, 0, 0, 0,
                                                    1, 1, 0, 0]
    # correctness untouched by priority scheduling
    for c, p in zip(clients, ptrs):
        np.testing.assert_array_equal(c.memcpy_d2h(p, 4),
                                      np.full(4, 3.0, np.float32))


def test_weight_equal_to_lookahead_never_waits():
    """Regression: a priority tenant with weight == lookahead_cycles must
    have hold budget 0 (not lookahead // weight == 1) — the documented
    zero-latency guarantee is weight >= lookahead, not weight >."""
    mgr = GuardianManager(total_slots=512, lookahead_cycles=2)
    prio = mgr.register_tenant("p", 32, weight=2)
    best = mgr.register_tenant("a", 32)
    for c in (prio, best):
        c.module_load("bump", bump)
    pp, pa = prio.malloc(4), best.malloc(4)
    prio.memcpy_h2d(pp, np.zeros(4, np.float32))
    best.memcpy_h2d(pa, np.zeros(4, np.float32))
    mgr.synchronize()
    mgr.scheduler.stats.queue_ages.clear()
    mgr.scheduler.dispatch_log.clear()
    prio.launch_kernel("bump", ptrs=[pp], args=(4,))
    for _ in range(3):
        best.launch_kernel("bump", ptrs=[pa], args=(4,))
    mgr.synchronize()
    log = list(mgr.scheduler.dispatch_log)
    # p's op dispatches in its submission cycle (with a's first op)
    assert log[0][:2] == ("p", "a")
    ages = list(mgr.scheduler.stats.queue_ages)
    assert ages[0] == 0        # the priority op never waited


def _run_lookahead_case(depths, weights, look):
    """Shared body for the deterministic sweep + hypothesis mirror:
    returns (scheduler, per-request (tenant, age) pairs)."""
    mgr = GuardianManager(total_slots=1024, lookahead_cycles=look)
    clients = []
    for i, w in enumerate(weights):
        clients.append(mgr.register_tenant(f"t{i}", 32, weight=w))
    ptrs = []
    for c in clients:
        c.module_load("bump", bump)
        p = c.malloc(4)
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        ptrs.append(p)
    mgr.synchronize()
    mgr.scheduler.dispatch_log.clear()
    mgr.scheduler.stats.queue_ages.clear()
    reqs = []
    for c, p, d in zip(clients, ptrs, depths):
        for _ in range(d):
            reqs.append(c.launch_kernel("bump", ptrs=[p], args=(4,)))
    mgr.run_queued()
    sched = mgr.scheduler
    dispatched = [t for batch in sched.dispatch_log for t in batch]
    ages = list(sched.stats.queue_ages)
    # every submitted launch dispatched exactly once (no starvation, no
    # leftovers) and the age log aligns with the dispatch log
    assert len(dispatched) == len(ages) == len(reqs) == sum(depths)
    assert sched.pending == 0
    return sched, list(zip(dispatched, ages))


def _hold_bound(look, w):
    """Mirror of BatchedLaunchScheduler._hold_budget."""
    if w <= 1:
        return look
    return 0 if w >= look else look // w


def _check_lookahead_invariants(depths, weights, look):
    sched, pairs = _run_lookahead_case(depths, weights, look)
    for tenant, age in pairs:
        w = weights[int(tenant[1:])]
        assert age <= _hold_bound(look, w), (depths, weights, look, pairs)


def test_lookahead_weighted_fairness_sweep():
    """Deterministic mirror of the hypothesis property: every dispatched
    launch waited at most lookahead // weight cycles — weighted fairness
    that lookahead can never starve."""
    cases = [
        ((3, 3, 3), (4, 1, 1), 3),
        ((5, 1, 0), (1, 2, 1), 2),
        ((2, 2, 2), (1, 1, 1), 1),
        ((4, 0, 4), (3, 1, 3), 3),
        ((1, 6, 2), (2, 1, 4), 4),
    ]
    for depths, weights, look in cases:
        _check_lookahead_invariants(depths, weights, look)


@settings(max_examples=25, deadline=None)
@given(
    depths=st.tuples(*[st.integers(min_value=0, max_value=5)] * 3),
    weights=st.tuples(*[st.integers(min_value=1, max_value=4)] * 3),
    look=st.integers(min_value=0, max_value=4),
)
def test_lookahead_weighted_fairness_property(depths, weights, look):
    if sum(depths) == 0:
        return
    _check_lookahead_invariants(depths, weights, look)


def test_adaptive_lookahead_off_by_default_and_static_knob_wins():
    mgr, _ = make_manager(2)
    assert mgr.scheduler.current_lookahead == 0
    mgr2, _ = make_manager(2, lookahead_cycles=3, adaptive_lookahead=True)
    # the static knob overrides adaptation entirely
    assert mgr2.scheduler.current_lookahead == 3
    mgr2.scheduler._adaptive_budget = 7
    assert mgr2.scheduler.current_lookahead == 3


def _ewma_mirror(series, alpha=0.5):
    """Host mirror of pressure.Ewma (seeded first sample)."""
    v = None
    for x in series:
        v = float(x) if v is None else alpha * x + (1 - alpha) * v
    return v or 0.0


def _derived_mirror(total_rate, max_fuse, cap):
    """Host mirror of pressure.derive_lookahead."""
    import math
    if total_rate <= 0 or max_fuse <= 1:
        return 0
    return max(0, min(math.ceil((max_fuse - 1) / total_rate), cap))


def test_adaptive_lookahead_budget_tracks_arrival_rates_exact():
    """Deterministic sweep: after each drain the scheduler's budget
    equals ceil((max_fuse-1)/sum(EWMA rates)) clamped to the cap — the
    documented derivation, mirrored in plain arithmetic."""
    from repro.core import derive_lookahead

    for pattern in ([(2, 2, 2)], [(1, 0, 0), (1, 0, 0)],
                    [(3, 1, 0), (0, 0, 0), (2, 2, 2)]):
        mgr, clients = make_manager(3, adaptive_lookahead=True,
                                    adaptive_lookahead_cap=4, max_fuse=8)
        for c in clients:
            c.module_load("bump", bump)
        ptrs = [c.malloc(4) for c in clients]
        for c, p in zip(clients, ptrs):
            c.memcpy_h2d(p, np.zeros(4, np.float32))
        mgr.synchronize()
        per_tenant = {c.tenant_id: [] for c in clients}
        for depths in pattern:
            for c, p, d in zip(clients, ptrs, depths):
                for _ in range(d):
                    c.launch_kernel("bump", ptrs=[p], args=(4,))
            mgr.run_queued()
            # mirror: every drain cycle in run_queued updates the EWMA;
            # the final budget reflects the last cycle's rates
        sched = mgr.scheduler
        total = sum(ew.value for ew in sched._arrival_ewma.values())
        expect = _derived_mirror(total, sched.max_fuse,
                                 sched.adaptive_lookahead_cap)
        assert sched.current_lookahead == expect
        assert sched.current_lookahead == derive_lookahead(
            (ew.value for ew in sched._arrival_ewma.values()),
            sched.max_fuse, sched.adaptive_lookahead_cap)
        assert sched.stats.summary()["lookahead_budget"] == float(expect)


def test_adaptive_lookahead_dense_traffic_keeps_budget_small():
    """Dense arrivals (every tenant submitting each cycle) fill batches
    within a cycle: the derived budget collapses to 1 — adaptation never
    inflates latency where the static tuning would be 0-1."""
    mgr, clients = make_manager(4, adaptive_lookahead=True,
                                adaptive_lookahead_cap=8, max_fuse=4)
    for c in clients:
        c.module_load("bump", bump)
    ptrs = [c.malloc(4) for c in clients]
    for c, p in zip(clients, ptrs):
        c.memcpy_h2d(p, np.zeros(4, np.float32))
    mgr.synchronize()
    for _ in range(4):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(4,))
        mgr.run_queued()
    assert mgr.scheduler.current_lookahead == 1
    # and every launch still dispatched within the budget
    assert all(a <= 1 for a in mgr.scheduler.stats.queue_ages)


def test_adaptive_lookahead_sparse_traffic_holds_for_fusion():
    """Sparse single-tenant-per-cycle traffic: the derived budget grows
    (capped), and under-filled batches hold across cycles — lookahead
    fusion happens with no static knob at all."""
    mgr, clients = make_manager(2, adaptive_lookahead=True,
                                adaptive_lookahead_cap=4, max_fuse=4)
    for c in clients:
        c.module_load("bump", bump)
    ptrs = [c.malloc(4) for c in clients]
    for c, p in zip(clients, ptrs):
        c.memcpy_h2d(p, np.zeros(4, np.float32))
    mgr.synchronize()
    # warm the EWMA: both tenants trickle one op per drain
    for _ in range(3):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(4,))
        mgr.run_queued()
    assert mgr.scheduler.current_lookahead >= 1
    base_fused = mgr.scheduler.stats.lookahead_fused
    # uneven depths in ONE drain: cycle 1's width-2 batch is held under
    # the derived budget and cycle 2's op joins it — lookahead fusion
    # with no static knob at all
    clients[0].launch_kernel("bump", ptrs=[ptrs[0]], args=(4,))
    clients[0].launch_kernel("bump", ptrs=[ptrs[0]], args=(4,))
    clients[1].launch_kernel("bump", ptrs=[ptrs[1]], args=(4,))
    mgr.run_queued()
    assert mgr.scheduler.stats.lookahead_fused > base_fused
    assert mgr.scheduler.pending == 0


def test_adaptive_lookahead_bit_identical_results():
    """Adaptation changes dispatch timing, never results: final arena
    equals the static-knob and no-lookahead drains."""
    arenas = []
    for kw in ({"adaptive_lookahead": True, "adaptive_lookahead_cap": 3},
               {"lookahead_cycles": 3}, {}):
        mgr, clients = make_manager(3, **kw)
        for i, c in enumerate(clients):
            c.module_load("bump", bump)
            p = c.malloc(8)
            c.memcpy_h2d(p, np.arange(8, dtype=np.float32) * (i + 1))
            for _ in range(i + 2):
                c.launch_kernel("bump", ptrs=[p], args=(8,))
        mgr.synchronize()
        arenas.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(arenas[0], arenas[1])
    np.testing.assert_array_equal(arenas[0], arenas[2])


def test_adaptive_lookahead_forgets_departed_tenants():
    mgr, clients = make_manager(3, adaptive_lookahead=True)
    for c in clients:
        c.module_load("bump", bump)
    ptrs = [c.malloc(4) for c in clients]
    for c, p in zip(clients, ptrs):
        c.memcpy_h2d(p, np.zeros(4, np.float32))
        c.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    assert "t0" in mgr.scheduler._arrival_ewma
    mgr.remove_tenant("t0")
    assert "t0" not in mgr.scheduler._arrival_ewma


def _run_adaptive_case(depth_rounds, cap):
    mgr, clients = make_manager(3, adaptive_lookahead=True,
                                adaptive_lookahead_cap=cap, max_fuse=4)
    for c in clients:
        c.module_load("bump", bump)
    ptrs = [c.malloc(4) for c in clients]
    for c, p in zip(clients, ptrs):
        c.memcpy_h2d(p, np.zeros(4, np.float32))
    mgr.synchronize()
    mgr.scheduler.stats.queue_ages.clear()
    n = 0
    for depths in depth_rounds:
        for c, p, d in zip(clients, ptrs, depths):
            for _ in range(d):
                c.launch_kernel("bump", ptrs=[p], args=(4,))
                n += 1
        mgr.run_queued()
    sched = mgr.scheduler
    assert sched.pending == 0
    ages = list(sched.stats.queue_ages)
    assert len(ages) == n
    # the latency invariant: no launch ever waits past the cap
    assert all(a <= cap for a in ages), (depth_rounds, cap, ages)


def test_adaptive_lookahead_latency_bounded_by_cap_sweep():
    cases = [
        ([(2, 0, 0), (0, 2, 0), (0, 0, 2)], 2),
        ([(1, 1, 1)] * 3, 1),
        ([(3, 0, 1), (0, 0, 0), (1, 2, 0)], 4),
        ([(1, 0, 0)] * 5, 3),
    ]
    for depth_rounds, cap in cases:
        _run_adaptive_case(depth_rounds, cap)


@settings(max_examples=15, deadline=None)
@given(
    rounds=st.lists(
        st.tuples(*[st.integers(min_value=0, max_value=3)] * 3),
        min_size=1, max_size=4),
    cap=st.integers(min_value=0, max_value=4),
)
def test_adaptive_lookahead_latency_property(rounds, cap):
    if sum(sum(r) for r in rounds) == 0:
        return
    _run_adaptive_case(rounds, cap)


def test_round_robin_interleave_weighted():
    from repro.core import round_robin_interleave

    by_tenant = {"t0": ["a0", "a1", "a2"], "t1": ["b0", "b1"],
                 "t2": ["c0"]}
    order = round_robin_interleave(by_tenant, weights={"t0": 2})
    assert order == ["a0", "a1", "b0", "c0", "a2", "b1"]
    assert round_robin_interleave(by_tenant, limit=3,
                                  weights={"t0": 2}) == ["a0", "a1", "b0"]
    # weights below 1 degrade to strict round-robin, inputs not consumed
    assert round_robin_interleave(by_tenant, weights={"t1": 0}) == \
        ["a0", "b0", "c0", "a1", "b1", "a2"]
    assert by_tenant["t0"] == ["a0", "a1", "a2"]


# ---------------------------------------------------------------------------
# Trusted-step jit + multi-engine fusion (scheduler level)
# ---------------------------------------------------------------------------


def test_trusted_requests_fuse_when_jitted():
    """Two tenants' trusted steps with equal signatures coalesce into one
    compiled device step (the multi-engine fused decode, scheduler
    view); results land on each request handle."""
    mgr = GuardianManager(total_slots=64)

    def step(arena, x):
        return arena, x * 2.0

    mgr.register_trusted_kernel("step", step)
    a = mgr.register_tenant("a", 8)
    b = mgr.register_tenant("b", 8)
    ra = a.launch_kernel("step", args=(jnp.ones((4,), jnp.float32),))
    rb = b.launch_kernel("step", args=(jnp.full((4,), 3.0, jnp.float32),))
    mgr.synchronize()
    st_ = mgr.scheduler.stats
    assert st_.fused_steps == 1 and list(st_.batch_widths) == [2]
    np.testing.assert_array_equal(np.asarray(ra.result),
                                  np.full(4, 2.0, np.float32))
    np.testing.assert_array_equal(np.asarray(rb.result),
                                  np.full(4, 6.0, np.float32))
    # the fused binary is cached under the trusted key
    assert any(k[0] == "trusted" for k in mgr.scheduler._fused_cache)


def test_trusted_requests_stay_single_when_eager():
    """jit_trusted=False is the eager fallback: trusted steps never fuse
    and execute unjitted through the per-launch path — same results."""
    mgr = GuardianManager(total_slots=64, jit_trusted=False)

    def step(arena, x):
        return arena, x * 2.0

    mgr.register_trusted_kernel("step", step)
    a = mgr.register_tenant("a", 8)
    b = mgr.register_tenant("b", 8)
    ra = a.launch_kernel("step", args=(jnp.ones((4,), jnp.float32),))
    rb = b.launch_kernel("step", args=(jnp.full((4,), 3.0, jnp.float32),))
    mgr.synchronize()
    st_ = mgr.scheduler.stats
    assert st_.fused_steps == 0 and st_.single_steps == 2
    np.testing.assert_array_equal(np.asarray(ra.result),
                                  np.full(4, 2.0, np.float32))
    np.testing.assert_array_equal(np.asarray(rb.result),
                                  np.full(4, 6.0, np.float32))
    entry = mgr.pointer_to_symbol["step"]
    assert not any(k[0] == "trusted" for k in entry.jit_cache)


def test_trusted_jit_matches_eager_results():
    """The compiled trusted step is bit-identical to the eager fallback —
    same arena bytes, same outputs (regression for the --no-jit path)."""
    outs, arenas = [], []
    for jit in (True, False):
        mgr = GuardianManager(total_slots=64, jit_trusted=jit)

        def step(arena, x, w):
            h = jnp.tanh(x @ w) + x
            return arena, h

        mgr.register_trusted_kernel("step", step)
        c = mgr.register_tenant("svc", 16)
        x = jnp.asarray(np.linspace(-1, 1, 32, dtype=np.float32)
                        .reshape(4, 8))
        w = jnp.asarray(np.linspace(1, -1, 64, dtype=np.float32)
                        .reshape(8, 8))
        req = c.launch_kernel("step", args=(x, w))
        mgr.synchronize()
        outs.append(np.asarray(req.result))
        arenas.append(np.asarray(mgr.arena.buf))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(arenas[0], arenas[1])


def test_trusted_pytree_operands_fuse_by_structure():
    """Trusted signatures hash pytree operands (params/cache/guard trees)
    by treedef + leaf structure: equal-structure steps fuse, different
    shapes stay apart."""
    mgr = GuardianManager(total_slots=64)

    def step(arena, tree):
        return arena, tree["x"] + tree["y"]

    mgr.register_trusted_kernel("step", step)
    a = mgr.register_tenant("a", 8)
    b = mgr.register_tenant("b", 8)
    t1 = {"x": jnp.ones((4,)), "y": jnp.zeros((4,))}
    t2 = {"x": jnp.full((4,), 2.0), "y": jnp.ones((4,))}
    t3 = {"x": jnp.ones((8,)), "y": jnp.zeros((8,))}   # different shape
    ra = a.launch_kernel("step", args=(t1,))
    rb = b.launch_kernel("step", args=(t2,))
    mgr.synchronize()
    assert mgr.scheduler.stats.fused_steps == 1
    np.testing.assert_array_equal(np.asarray(ra.result), np.ones(4))
    np.testing.assert_array_equal(np.asarray(rb.result),
                                  np.full(4, 3.0))
    ra2 = a.launch_kernel("step", args=(t1,))
    rb2 = b.launch_kernel("step", args=(t3,))
    mgr.synchronize()
    assert mgr.scheduler.stats.fused_steps == 1   # no second fused step
    np.testing.assert_array_equal(np.asarray(rb2.result), np.ones(8))
    np.testing.assert_array_equal(np.asarray(ra2.result), np.ones(4))


# ---------------------------------------------------------------------------
# LRU-bounded jit caches
# ---------------------------------------------------------------------------


def test_lru_cache_semantics():
    lru = LRUCache(2)
    lru["a"] = 1
    lru["b"] = 2
    assert lru["a"] == 1            # refreshes recency
    lru["c"] = 3                    # evicts b (coldest)
    assert "b" not in lru and "a" in lru and "c" in lru
    assert lru.evictions == 1
    lru["a"] = 10                   # overwrite refreshes, no eviction
    assert lru["a"] == 10 and lru.evictions == 1
    del lru["c"]                    # purge-path deletion still works
    assert list(lru) == ["a"]
    with pytest.raises(ValueError):
        LRUCache(0)


def test_jit_cache_lru_bound_evicts_and_counts():
    """jit_cache_capacity bounds each kernel entry's compiled-variant
    cache: churning operand signatures evicts the coldest binaries (a
    recompile on reuse, never an error) and the eviction stat reports."""
    mgr = GuardianManager(total_slots=64, jit_cache_capacity=2)

    def step(arena, x):
        return arena, x + 1.0

    mgr.register_trusted_kernel("step", step)
    c = mgr.register_tenant("svc", 16)
    for n in (2, 4, 8, 16):         # 4 distinct signatures, capacity 2
        req = c.launch_kernel("step", args=(jnp.zeros((n,), jnp.float32),))
        mgr.synchronize()
        np.testing.assert_array_equal(np.asarray(req.result),
                                      np.ones(n, np.float32))
    entry = mgr.pointer_to_symbol["step"]
    assert len(entry.jit_cache) == 2
    assert entry.jit_cache.evictions == 2
    stats = mgr.jit_cache_stats()
    assert stats["capacity"] == 2 and stats["evictions"] == 2
    assert stats["per_kernel"]["step"] == 2
    # an evicted signature recompiles transparently
    req = c.launch_kernel("step", args=(jnp.zeros((2,), jnp.float32),))
    mgr.synchronize()
    np.testing.assert_array_equal(np.asarray(req.result),
                                  np.ones(2, np.float32))
    assert mgr.jit_cache_stats()["evictions"] == 3
    # the scheduler's fused-step cache is bounded the same way
    assert isinstance(mgr.scheduler._fused_cache, LRUCache)
    assert stats["fused_capacity"] == mgr.scheduler._fused_cache.capacity


def test_signature_distinguishes_policies():
    """Policies never mix within a batch (the policy is part of the batch
    signature) — but every fencing policy is fusable now, MODULO included
    (via the magic row table); only NONE degrades to the native path."""
    r1 = LaunchRequest(tenant_id="a", name="k", policy=FencePolicy.BITWISE,
                       entry=None, part=None, call_args=(jnp.int32(1), 4))
    r2 = LaunchRequest(tenant_id="b", name="k", policy=FencePolicy.MODULO,
                       entry=None, part=None, call_args=(jnp.int32(2), 4))
    r3 = LaunchRequest(tenant_id="b", name="k", policy=FencePolicy.BITWISE,
                       entry=None, part=None, call_args=(jnp.int32(3), 4))
    r4 = LaunchRequest(tenant_id="b", name="k", policy=FencePolicy.NONE,
                       entry=None, part=None, call_args=(jnp.int32(4), 4))
    assert r1.signature != r2.signature
    assert r1.signature == r3.signature
    assert r1.fusable and r3.fusable and r2.fusable
    assert not r4.fusable
