"""Optional-``hypothesis`` shim for the property-test modules.

The tier-1 suite must collect and run on a bare interpreter (jax + numpy +
pytest only).  ``hypothesis`` is a dev-extra (see requirements-dev.txt):
when it is importable the property tests run as real property tests; when
it is absent they are collected and *skipped* cleanly, and the
deterministic seeded-sweep mirrors in each test module keep the same
invariants covered.

Usage (replaces ``from hypothesis import given, settings, strategies as st``):

    from _hyp import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="hypothesis not installed (optional dev dependency; "
               "deterministic sweep mirrors still run)")

    class _DummyStrategy:
        """Stands in for a strategy object; only needs to exist at import."""

        def __repr__(self):
            return "<dummy strategy (hypothesis not installed)>"

    class _Strategies:
        """``st.<anything>(...)`` -> dummy strategy, evaluated at import."""

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return _DummyStrategy()

            return make

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            return _SKIP(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
