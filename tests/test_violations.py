"""ViolationLog accounting — device-side per-tenant per-kind OOB telemetry
(DESIGN.md §Fault-containment): counts match injected OOB indices exactly,
zero false positives under in-bounds (BITWISE-safe) traffic, and row
lifecycle (assign / release / reset).

Deterministic seeded sweeps mirror every hypothesis property (the tier-1
suite runs without hypothesis; see tests/_hyp.py).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import (
    FencePolicy,
    GuardianManager,
    ThresholdPolicy,
    ViolationKind,
    ViolationLog,
)

TOTAL = 512


def make_mixed_kernel():
    """One fenced access of every kind the sandbox instruments:
    gather + scatter over ``idx``, dynamic slice + update at ``start``."""
    import jax

    def mixed(arena, idx, start, n):
        vals = jnp.take(arena, idx, axis=0)                        # gather
        arena = arena.at[idx].set(vals + 1.0)                      # scatter
        window = jax.lax.dynamic_slice_in_dim(arena, start, 4, axis=0)
        arena = jax.lax.dynamic_update_slice_in_dim(
            arena, window * 1.0, start, axis=0)                    # update
        return arena, None

    return mixed


def setup_manager(**kw):
    kw.setdefault("total_slots", TOTAL)
    kw.setdefault("policy", FencePolicy.CHECK)
    # accounting tests observe the log itself; park the threshold out of
    # reach so the QuarantineManager never reacts (tests/test_quarantine.py
    # covers the reactions)
    kw.setdefault("quarantine_policy",
                  ThresholdPolicy(quarantine_after=1 << 30))
    mgr = GuardianManager(**kw)
    a = mgr.register_tenant("a", 128)
    b = mgr.register_tenant("b", 128)
    a.module_load("mixed", make_mixed_kernel())
    b.module_load("mixed", make_mixed_kernel())
    return mgr, a, b


def _launch_mixed(mgr, client, idx, start):
    client.launch_kernel(
        "mixed", args=(jnp.asarray(idx, jnp.int32), jnp.int32(start), 0))
    mgr.run_queued()      # drain without the final d2h sync


def _expected(part, idx, start):
    n_oob = int(sum(1 for i in idx if not (part.base <= i < part.end)))
    start_oob = int(not (part.base <= start < part.end))
    return {"gather": n_oob, "scatter": n_oob,
            "slice": start_oob, "update": start_oob}


# ---------------------------------------------------------------------------
# Exact accounting (deterministic sweep — hypothesis mirror below)
# ---------------------------------------------------------------------------


def test_counts_match_injected_oob_exactly_sweep():
    mgr, a, _ = setup_manager()
    part = mgr.bounds.lookup("a")
    rng = np.random.default_rng(0)
    expected = {"gather": 0, "scatter": 0, "slice": 0, "update": 0}
    for _ in range(6):
        n = int(rng.integers(1, 9))
        inside = rng.integers(part.base, part.end, size=n)
        oob_mask = rng.random(n) < 0.4
        idx = np.where(oob_mask, inside + part.size, inside)
        start = int(rng.choice([part.base + 1, part.end + 7]))
        for k, v in _expected(part, idx, start).items():
            expected[k] += v
        _launch_mixed(mgr, a, idx, start)
    got = mgr.violog.counts("a")
    assert got == expected
    assert mgr.violog.total("a") == sum(expected.values())


def test_zero_false_positives_under_safe_traffic():
    """In-bounds traffic (what BITWISE would pass through untouched) must
    log nothing — detection has no noise floor."""
    mgr, a, b = setup_manager()
    for client in (a, b):
        part = mgr.bounds.lookup(client.tenant_id)
        rng = np.random.default_rng(1)
        for _ in range(4):
            idx = rng.integers(part.base, part.end, size=8)
            _launch_mixed(mgr, client, idx, part.base + 2)
    snap = mgr.violog.snapshot()
    assert (snap == 0).all()
    assert mgr.quarantine.state_of("a").admissible
    assert mgr.quarantine.state_of("b").admissible


def test_attribution_lands_on_the_offending_tenant_only():
    mgr, a, b = setup_manager()
    pa, pb = mgr.bounds.lookup("a"), mgr.bounds.lookup("b")
    # a attacks b's range; b stays in bounds — same fused drain
    _launch_mixed(mgr, a, np.arange(pb.base, pb.base + 8), pa.base)
    _launch_mixed(mgr, b, np.arange(pb.base, pb.base + 8), pb.base)
    snap = mgr.violog.snapshot()
    assert mgr.violog.counts("a", snap=snap)["gather"] == 8
    assert mgr.violog.counts("a", snap=snap)["scatter"] == 8
    assert mgr.violog.total("b", snap=snap) == 0


@given(st.lists(st.booleans(), min_size=1, max_size=8),
       st.booleans())
@settings(max_examples=15, deadline=None)
def test_counts_match_injected_oob_property(oob_mask, start_oob):
    mgr, a, _ = setup_manager()
    part = mgr.bounds.lookup("a")
    idx = np.array([part.end + 3 if bad else part.base + j
                    for j, bad in enumerate(oob_mask)], np.int32)
    start = part.end + 1 if start_oob else part.base
    _launch_mixed(mgr, a, idx, start)
    assert mgr.violog.counts("a") == _expected(part, idx, start)


# ---------------------------------------------------------------------------
# Log row lifecycle
# ---------------------------------------------------------------------------


def test_row_recycling_and_reset():
    log = ViolationLog(capacity=2)
    r0 = log.assign("a")
    assert log.assign("a") == r0            # idempotent
    log.add("a", np.array([1, 2, 3, 4], np.int32))
    assert log.total("a") == 10
    log.reset("a")
    assert log.total("a") == 0
    log.add("a", np.array([5, 0, 0, 0], np.int32))
    log.release("a")
    r1 = log.assign("b")                    # recycled row arrives zeroed
    assert r1 == r0 or log.assign("c") == r0
    snap = log.snapshot()
    assert (snap == 0).all()


def test_assign_past_capacity_grows_table():
    """The (T, K) device table is elastic: assigning past capacity
    doubles it instead of raising; existing rows keep their indices and
    their counts (regression test for the grow path)."""
    log = ViolationLog(capacity=1)
    r0 = log.assign("a")
    log.add("a", np.array([1, 2, 3, 4], np.int32))
    r1 = log.assign("b")                    # grows, never raises
    assert log.capacity == 2
    assert r1 != r0
    assert log.row_of("a") == r0            # index stable across the grow
    assert log.total("a") == 10             # counts survive the grow
    assert log.total("b") == 0
    assert log.buf.shape == (2, 4)
    log.release("a")
    assert log.assign("c") == r0            # freed row still recycles


def test_register_past_log_capacity_grows_and_attributes():
    """Registering more co-resident tenants than max_tenants grows the
    log rather than refusing the tenant — and CHECK attribution keeps
    landing on the right (pre- and post-growth) rows."""
    mgr = GuardianManager(total_slots=512, max_tenants=2,
                          policy=FencePolicy.CHECK,
                          quarantine_policy=ThresholdPolicy(
                              quarantine_after=1 << 30))
    clients = {t: mgr.register_tenant(t, 64) for t in ("a", "b", "c")}
    assert mgr.violog.capacity == 4
    assert sorted(mgr.violog.row_of(t) for t in clients) == [0, 1, 2]
    for c in clients.values():
        c.module_load("mixed", make_mixed_kernel())
    pa, pc = mgr.bounds.lookup("a"), mgr.bounds.lookup("c")
    # "a" (pre-growth row) and "c" (post-growth row) both go OOB
    _launch_mixed(mgr, clients["a"], np.full(3, pa.end + 1), pa.base)
    _launch_mixed(mgr, clients["c"], np.full(5, pc.end + 9), pc.base)
    snap = mgr.violog.snapshot()
    assert mgr.violog.counts("a", snap=snap)["gather"] == 3
    assert mgr.violog.counts("c", snap=snap)["gather"] == 5
    assert mgr.violog.total("b", snap=snap) == 0


def test_duplicate_registration_cannot_reset_counters():
    """A failed duplicate register_tenant must not touch the live tenant's
    log row or lifecycle record — otherwise a rogue tenant could reset its
    own violation counters by re-registering its id."""
    mgr, a, _ = setup_manager()
    part = mgr.bounds.lookup("a")
    _launch_mixed(mgr, a, np.full(4, part.end + 1, np.int32), part.base)
    before = mgr.violog.total("a")
    assert before == 8                     # 4 gather + 4 scatter
    with pytest.raises(ValueError):
        mgr.register_tenant("a", 64)       # duplicate partition
    assert mgr.violog.row_of("a") is not None
    assert mgr.violog.total("a") == before
    assert mgr.quarantine.state_of("a") is not None


def test_register_failure_leaks_nothing():
    """A partition failure during register_tenant must not leak the log
    row or poison the tenant id (the row is taken before bounds.create,
    so the rollback must release exactly what this call created)."""
    from repro.core import OutOfArenaMemory

    mgr = GuardianManager(total_slots=512, max_tenants=2)
    mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)
    free = mgr.bounds.free_slots()
    rows = len(mgr.violog.tenants())
    with pytest.raises(OutOfArenaMemory):
        mgr.register_tenant("c", 1024)       # bigger than the arena
    assert mgr.bounds.free_slots() == free   # no partition leaked
    assert len(mgr.violog.tenants()) == rows  # no log row leaked
    assert mgr.quarantine.state_of("c") is None   # no phantom record
    assert "c" not in mgr.violation_report()["tenants"]
    c = mgr.register_tenant("c", 64)         # id stays usable
    assert c is mgr._clients["c"]


def test_dirty_flag_gates_polling():
    """BITWISE traffic never marks the log dirty — the quarantine poll is
    skipped entirely (no device sync on fenced-only drains)."""
    mgr = GuardianManager(total_slots=TOTAL, policy=FencePolicy.BITWISE)
    a = mgr.register_tenant("a", 128)
    mgr.register_tenant("b", 128)
    a.module_load("mixed", make_mixed_kernel())
    part = mgr.bounds.lookup("a")
    assert not mgr.violog.dirty
    _launch_mixed(mgr, a, np.arange(part.base, part.base + 4), part.base)
    assert not mgr.violog.dirty             # BITWISE contains, never logs
    assert (mgr.violog.snapshot() == 0).all()


def test_operator_reads_do_not_suppress_poll():
    """violation_report()/snapshot() must not clear the dirty flag — an
    operator inspecting the log between polls would otherwise defer
    containment of an already-over-threshold tenant."""
    mgr, a, _ = setup_manager(quarantine_poll_every=100)   # poll deferred
    part = mgr.bounds.lookup("a")
    _launch_mixed(mgr, a, np.full(4, part.end + 1, np.int32), part.base)
    assert mgr.violog.dirty
    mgr.violation_report()                   # operator look
    assert mgr.violog.dirty                  # poll gate still armed
    mgr.quarantine.poll()                    # only the poller consumes it
    assert not mgr.violog.dirty


def test_violation_kind_order_is_stable():
    """Report columns are part of the operator contract."""
    assert [k.name.lower() for k in ViolationKind] == [
        "gather", "scatter", "slice", "update"]
