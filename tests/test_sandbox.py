"""Jaxpr sandboxer ("PTX-patcher") tests — Guardian §4.3."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fence import FenceParams, FencePolicy
from repro.core.sandbox import SandboxError, sandbox, sandbox_report


def _params(base=64, size=64):
    return FenceParams(base=base, size=size)


def test_gather_is_fenced():
    def kernel(arena, ptr):
        idx = ptr + jnp.arange(8, dtype=jnp.int32)
        return arena, jnp.take(arena, idx, axis=0)

    arena = jnp.arange(256.0)
    sb = sandbox(kernel, arena_argnums=(0,))
    # in-partition: identical to native
    (a1, out), ok = sb(_params(), arena, jnp.int32(64))
    np.testing.assert_array_equal(out, np.arange(64.0, 72.0))
    # out-of-partition: wrapped inside [64, 128)
    (_, out2), _ = sb(_params(), arena, jnp.int32(200))
    assert ((np.asarray(out2) >= 64) & (np.asarray(out2) < 128)).all()


def test_scatter_is_fenced():
    def kernel(arena, ptr):
        idx = ptr + jnp.arange(4, dtype=jnp.int32)
        return arena.at[idx].set(-1.0), None

    arena = jnp.zeros(256)
    sb = sandbox(kernel, arena_argnums=(0,))
    (a1, _), _ = sb(_params(), arena, jnp.int32(250))  # would hit [250,254)
    touched = np.nonzero(np.asarray(a1) == -1.0)[0]
    assert ((touched >= 64) & (touched < 128)).all()


def test_dynamic_slice_is_fenced_and_pinned():
    def kernel(arena, start):
        return arena, jax.lax.dynamic_slice_in_dim(arena, start, 16)

    arena = jnp.arange(256.0)
    sb = sandbox(kernel, arena_argnums=(0,))
    (_, out), _ = sb(_params(), arena, jnp.int32(500))
    vals = np.asarray(out)
    assert vals.min() >= 64 and vals.max() < 128


def test_double_indirection_fenced():
    """Indices loaded from the arena itself (the paper's hardest case)."""
    def kernel(arena, cols_ptr, x_ptr):
        cols = jnp.take(arena, cols_ptr + jnp.arange(4, dtype=jnp.int32),
                        axis=0).astype(jnp.int32)
        return arena, jnp.take(arena, x_ptr + cols, axis=0)

    arena = jnp.arange(256.0).at[64:68].set(200.0)  # poisoned indices
    sb = sandbox(kernel, arena_argnums=(0,))
    (_, out), _ = sb(_params(), arena, jnp.int32(64), jnp.int32(0))
    assert ((np.asarray(out) >= 64) & (np.asarray(out) < 128)).all()


def test_check_policy_reports():
    def kernel(arena, ptr):
        return arena, jnp.take(arena, ptr + jnp.arange(4, dtype=jnp.int32),
                               axis=0)

    arena = jnp.arange(256.0)
    sb = sandbox(kernel, arena_argnums=(0,), policy=FencePolicy.CHECK)
    _, ok = sb(_params(), arena, jnp.int32(64))
    assert bool(ok)
    _, ok = sb(_params(), arena, jnp.int32(200))
    assert not bool(ok)


def test_report_counts():
    def kernel(arena, ptr):
        idx = ptr + jnp.arange(4, dtype=jnp.int32)
        vals = jnp.take(arena, idx, axis=0)
        arena = arena.at[idx].set(vals * 2)
        sl = jax.lax.dynamic_slice_in_dim(arena, ptr, 4)
        return arena, sl

    rep = sandbox_report(kernel, (jnp.zeros(64), jnp.int32(0)))
    assert rep.fenced_gathers == 1
    assert rep.fenced_scatters == 1
    assert rep.fenced_dynamic_slices == 1
    assert rep.fenced_total == 3


def test_private_tensors_not_fenced():
    """Indexing tenant-private tensors is untouched (XLA-safe already)."""
    def kernel(arena, private, ptr):
        return arena, jnp.take(private, ptr, axis=0)

    rep = sandbox_report(kernel,
                         (jnp.zeros(64), jnp.zeros(16), jnp.int32(0)))
    assert rep.fenced_total == 0


def test_loop_carried_arena_supported():
    """scan with the arena in the carry is interpreted, not rejected."""
    def kernel(arena, n):
        def body(a, _):
            return a, None
        a, _ = jax.lax.scan(body, arena, jnp.arange(4))
        return a, None

    sb = sandbox(kernel, arena_argnums=(0,))
    (a, _), ok = sb(_params(), jnp.zeros(64), jnp.int32(0))
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(a), np.zeros(64))


def test_scan_gather_fenced_per_iteration():
    """Tainted gathers inside a scan body are fenced on every iteration."""
    def kernel(arena, ptr):
        def body(carry, x):
            return carry + 1, jnp.take(arena, carry + x, axis=0)
        _, ys = jax.lax.scan(body, ptr, jnp.arange(4, dtype=jnp.int32))
        return arena, ys

    arena = jnp.arange(256.0)
    sb = sandbox(kernel, arena_argnums=(0,))
    (_, ys), ok = sb(_params(), arena, jnp.int32(200))
    assert ((np.asarray(ys) >= 64) & (np.asarray(ys) < 128)).all()

    sbc = sandbox(kernel, arena_argnums=(0,), policy=FencePolicy.CHECK,
                  count_violations=True)
    (_, _), ok, counts = sbc(_params(), arena, jnp.int32(200))
    assert not bool(ok)
    assert int(counts[0]) == 4   # one violating gather per iteration
    (_, _), ok2, counts2 = sbc(_params(), arena, jnp.int32(64))
    assert bool(ok2) and int(np.asarray(counts2).sum()) == 0


def test_while_loop_fenced_and_counted():
    def kernel(arena, ptr):
        def cond(state):
            i, acc = state
            return i < ptr + 4

        def body(state):
            i, acc = state
            return i + 1, acc + jnp.take(arena, i, axis=0)

        _, acc = jax.lax.while_loop(cond, body, (ptr, jnp.float32(0)))
        return arena, acc

    arena = jnp.arange(256.0)
    sbc = sandbox(kernel, arena_argnums=(0,), policy=FencePolicy.CHECK,
                  count_violations=True)
    (_, _), ok, counts = sbc(_params(), arena, jnp.int32(200))
    assert not bool(ok) and int(counts[0]) == 4


def test_cond_branches_fenced():
    def kernel(arena, ptr, flag):
        def taken(p):
            return jnp.take(arena, p, axis=0)

        def skipped(p):
            return jnp.float32(0.0)

        return arena, jax.lax.cond(flag > 0, taken, skipped, ptr)

    arena = jnp.arange(256.0)
    sbc = sandbox(kernel, arena_argnums=(0,), policy=FencePolicy.CHECK)
    _, ok = sbc(_params(), arena, jnp.int32(200), jnp.int32(1))
    assert not bool(ok)          # executed branch violates
    _, ok2 = sbc(_params(), arena, jnp.int32(200), jnp.int32(0))
    assert bool(ok2)             # untaken branch never runs its access


def test_reshape_splitting_dim0_keeps_taint():
    """reshape away the slot dim must NOT launder the arena lineage."""
    def kernel(arena, ptr):
        folded = arena.reshape(2, -1)          # splits dim 0
        return arena, jax.lax.dynamic_slice(folded, (ptr, jnp.int32(0)),
                                            (1, 8))

    import warnings as _w
    from repro.core.sandbox import GuardianTaintWarning
    with pytest.warns(GuardianTaintWarning):
        rep = sandbox_report(kernel, (jnp.zeros(64), jnp.int32(0)))
    assert rep.fenced_dynamic_slices == 1      # still fenced (taint kept)


def test_transpose_demoting_dim0_keeps_taint():
    def kernel(arena, ptr):
        flipped = arena.T                       # (64, 4) -> (4, 64)
        return arena, jax.lax.dynamic_slice(flipped, (ptr, jnp.int32(0)),
                                            (1, 8))

    from repro.core.sandbox import GuardianTaintWarning
    with pytest.warns(GuardianTaintWarning):
        rep = sandbox_report(kernel, (jnp.zeros((64, 4)), jnp.int32(0)))
    assert rep.fenced_dynamic_slices == 1


def test_nested_call_instrumented():
    """Fences land inside jitted library wrappers (implicit-call case)."""
    @jax.jit
    def inner(arena, idx):
        return jnp.take(arena, idx, axis=0)

    def kernel(arena, ptr):
        return arena, inner(arena, ptr + jnp.arange(4, dtype=jnp.int32))

    arena = jnp.arange(256.0)
    sb = sandbox(kernel, arena_argnums=(0,))
    (_, out), _ = sb(_params(), arena, jnp.int32(200))
    assert ((np.asarray(out) >= 64) & (np.asarray(out) < 128)).all()
