"""Property tests for the pow2 buddy allocator + partition bounds table
(Guardian §4.2.1 invariants I1/I2).

Hypothesis properties skip when the optional dep is absent; deterministic
seeded-sweep mirrors below keep I1/I2 covered unconditionally.
"""

import random

import pytest
from _hyp import given, settings, st

from repro.core.partition import (
    BuddyAllocator,
    IntraPartitionAllocator,
    OutOfArenaMemory,
    Partition,
    PartitionBoundsTable,
    UnknownTenant,
    is_pow2,
    next_pow2,
)


def test_next_pow2():
    assert next_pow2(1) == 1
    assert next_pow2(2) == 2
    assert next_pow2(3) == 4
    assert next_pow2(1023) == 1024
    with pytest.raises(ValueError):
        next_pow2(0)


@given(st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                max_size=20))
@settings(max_examples=50, deadline=None)
def test_buddy_invariants(sizes):
    """Every allocated block is pow2-sized and size-aligned (I1 + I2)."""
    alloc = BuddyAllocator(1024)
    blocks = []
    for s in sizes:
        try:
            base, size = alloc.alloc(s)
        except OutOfArenaMemory:
            continue
        assert is_pow2(size) and size >= s          # I1
        assert base % size == 0                     # I2
        blocks.append((base, size))
    # no overlaps
    spans = sorted(blocks)
    for (b1, s1), (b2, _s2) in zip(spans, spans[1:]):
        assert b1 + s1 <= b2


@given(st.lists(st.integers(min_value=1, max_value=128), min_size=1,
                max_size=30), st.randoms())
@settings(max_examples=50, deadline=None)
def test_buddy_free_coalesces(sizes, rnd):
    """Alloc-all / free-all returns the arena to one maximal block."""
    alloc = BuddyAllocator(2048)
    bases = []
    for s in sizes:
        try:
            base, _ = alloc.alloc(s)
            bases.append(base)
        except OutOfArenaMemory:
            break
    rnd.shuffle(bases)
    for b in bases:
        alloc.free(b)
    assert alloc.free_slots() == 2048
    # after full coalescing a max-size alloc succeeds
    base, size = alloc.alloc(2048)
    assert (base, size) == (0, 2048)


def test_partition_validation():
    with pytest.raises(ValueError):
        Partition("t", base=0, size=3)       # not pow2
    with pytest.raises(ValueError):
        Partition("t", base=4, size=8)       # misaligned
    p = Partition("t", base=8, size=8)
    assert p.mask == 7 and p.end == 16
    assert p.contains(8) and p.contains(15) and not p.contains(16)
    assert p.contains(8, 16) and not p.contains(8, 17)


def test_bounds_table_lifecycle():
    tbl = PartitionBoundsTable(256)
    a = tbl.create("a", 60)     # -> 64
    b = tbl.create("b", 64)
    assert a.size == 64 and b.size == 64
    assert a.base != b.base
    with pytest.raises(ValueError):
        tbl.create("a", 8)      # duplicate tenant
    assert set(tbl.tenants()) == {"a", "b"}
    tbl.destroy("a")
    with pytest.raises(UnknownTenant):
        tbl.lookup("a")
    arrays = tbl.bounds_arrays()
    assert arrays["tenant_ids"] == ["b"]
    assert arrays["mask"][0] == b.size - 1


def test_intra_partition_allocator():
    part = Partition("t", base=64, size=64)
    sub = IntraPartitionAllocator(part)
    x = sub.alloc(10)
    y = sub.alloc(20)
    assert x != y
    sub.free(x)
    sub.free(y)
    assert sub.alloc(64) == 0   # fully coalesced
    with pytest.raises(OutOfArenaMemory):
        sub.alloc(1)


@given(st.integers(min_value=1, max_value=512))
@settings(max_examples=50, deadline=None)
def test_mask_wraps_into_partition(size_req):
    """The exported (base, mask) satisfy the paper's wrap guarantee for
    every possible int32 index."""
    tbl = PartitionBoundsTable(1024)
    part = tbl.create("t", size_req)
    for idx in (-5, 0, 1, part.base, part.end, part.end + 1, 2**31 - 1):
        fenced = (idx & part.mask) | part.base
        assert part.base <= fenced < part.end
    # identity inside
    for idx in (part.base, part.base + part.size // 2, part.end - 1):
        assert ((idx & part.mask) | part.base) == idx


# ---------------------------------------------------------------------------
# Deterministic seeded-sweep mirrors (always run, no hypothesis needed).
# ---------------------------------------------------------------------------


def test_buddy_invariants_sweep():
    """I1 + I2 for every allocated block across seeded size mixes."""
    rnd = random.Random(0)
    for trial in range(20):
        alloc = BuddyAllocator(1024)
        blocks = []
        for _ in range(rnd.randint(1, 20)):
            s = rnd.randint(1, 64)
            try:
                base, size = alloc.alloc(s)
            except OutOfArenaMemory:
                continue
            assert is_pow2(size) and size >= s          # I1
            assert base % size == 0                     # I2
            blocks.append((base, size))
        spans = sorted(blocks)
        for (b1, s1), (b2, _s2) in zip(spans, spans[1:]):
            assert b1 + s1 <= b2                        # no overlaps


def test_buddy_free_coalesces_sweep():
    """Alloc-all / shuffled-free-all returns the arena to one max block."""
    rnd = random.Random(1)
    for trial in range(20):
        alloc = BuddyAllocator(2048)
        bases = []
        for _ in range(rnd.randint(1, 30)):
            try:
                base, _ = alloc.alloc(rnd.randint(1, 128))
                bases.append(base)
            except OutOfArenaMemory:
                break
        rnd.shuffle(bases)
        for b in bases:
            alloc.free(b)
        assert alloc.free_slots() == 2048
        base, size = alloc.alloc(2048)
        assert (base, size) == (0, 2048)
        alloc.free(0)


def test_mask_wraps_into_partition_sweep():
    rnd = random.Random(2)
    size_reqs = [1, 2, 3, 5, 8, 100, 511, 512] + \
        [rnd.randint(1, 512) for _ in range(12)]
    for size_req in size_reqs:
        tbl = PartitionBoundsTable(1024)
        part = tbl.create("t", size_req)
        for idx in (-5, 0, 1, part.base, part.end, part.end + 1,
                    2**31 - 1):
            fenced = (idx & part.mask) | part.base
            assert part.base <= fenced < part.end
        for idx in (part.base, part.base + part.size // 2, part.end - 1):
            assert ((idx & part.mask) | part.base) == idx
