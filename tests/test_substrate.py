"""Substrate tests: optimizers, schedules, data determinism, checkpoint
atomicity/async, gradient compression, HLO analyzer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticLM
from repro.distributed.compress import (
    compress_roundtrip,
    dequantize_int8,
    quantize_int8,
)
from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    constant,
    cosine,
    global_norm,
    wsd,
)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt,tol", [
    (lambda: adamw(constant(0.1), weight_decay=0.0), 1e-2),
    (lambda: adafactor(constant(0.5)), 0.5),
])
def test_optimizer_minimizes_quadratic(make_opt, tol):
    opt = make_opt()
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    start = float(loss_fn(params))
    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    final = float(loss_fn(params))
    assert final < tol and final < start / 50


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 6.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedules():
    f = cosine(1.0, warmup=10, total=100)
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-3)
    g = wsd(1.0, warmup=10, stable=50, decay=40)
    assert float(g(30)) == pytest.approx(1.0)
    assert float(g(100)) < 0.02


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_exact():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    s1 = SyntheticLM(cfg)
    s2 = SyntheticLM(cfg)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(s1.batch(step)["tokens"],
                                      s2.batch(step)["tokens"])


def test_data_host_shards_disjoint():
    full = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=1)
    h0 = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=1,
                    host_index=0, host_count=2)
    h1 = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=1,
                    host_index=1, host_count=2)
    assert h0.local_batch == 4
    b0 = SyntheticLM(h0).batch(2)["tokens"]
    b1 = SyntheticLM(h1).batch(2)["tokens"]
    assert not np.array_equal(b0, b1)


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=128, global_batch=4, seed=0)
    src = SyntheticLM(cfg)
    toks = src.batch(0)["tokens"]
    hits = (src._succ[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.5   # the 70% grammar is visible


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "s": jnp.int32(7),
            "nested": {"b": jnp.ones(4)}}
    store.save(10, tree)
    got, step = store.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(got["w"], np.arange(6.0).reshape(2, 3))
    assert int(got["s"]) == 7


def test_checkpoint_async_and_retention(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(8)}
    for s in (1, 2, 3, 4):
        store.save_async(s, {"x": jnp.full(8, float(s))})
    store.wait()
    assert store.steps() == [3, 4]
    got, step = store.restore(tree)
    assert step == 4 and float(got["x"][0]) == 4.0


def test_checkpoint_partial_write_ignored(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(5, {"x": jnp.ones(3)})
    # a crashed writer leaves a tmp dir — must be invisible + cleaned
    os.makedirs(tmp_path / "step_000000000009.tmp-zzz")
    assert store.latest_step() == 5
    store.save(6, {"x": jnp.ones(3)})
    assert not any(".tmp-" in n for n in os.listdir(tmp_path))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"x": jnp.ones(3)})
    with pytest.raises(ValueError):
        store.restore({"x": jnp.ones(4)})


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=2000),
       st.floats(min_value=0.01, max_value=100.0))
@settings(max_examples=25, deadline=None)
def test_int8_quantization_error_bound(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s, size = quantize_int8(x)
    y = dequantize_int8(q, s, size, x.shape, x.dtype)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= blockmax / 127.0 + 1e-6


@pytest.mark.parametrize("n,scale", [(1, 0.01), (7, 1.0), (255, 31.4),
                                     (2000, 100.0)])
def test_int8_quantization_error_bound_sweep(n, scale):
    """Deterministic mirror of the quantization property (always runs)."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s, size = quantize_int8(x)
    y = dequantize_int8(q, s, size, x.shape, x.dtype)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the running compressed sum tracks the true sum
    (residual stays bounded)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(256)
    true_acc = np.zeros(256)
    comp_acc = np.zeros(256)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=256), jnp.float32)
        approx, err = compress_roundtrip(g + err)
        true_acc += np.asarray(g)
        comp_acc += np.asarray(approx)
    # total drift is exactly the final residual — bounded, not growing
    np.testing.assert_allclose(comp_acc + np.asarray(err), true_acc,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(ws, x):
        def body(x, w):
            return jax.nn.relu(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
    costs = analyze_hlo(compiled.as_text())
    want = 5 * 2 * 8 * 64 * 64     # trips x 2mnk
    assert abs(costs.flops - want) / want < 0.05
