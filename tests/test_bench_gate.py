"""CI perf-regression gate (benchmarks/check_regression.py): pure
comparison logic — parsing, thresholds, normalization, width gating, and
the injected-slowdown self-test the perf-gate CI job runs."""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import (  # noqa: E402
    Row,
    compare,
    parse_rows,
    trend_csv,
)

BASELINE = """\
name,us_per_call,derived
sched.roundrobin.2t,476.52,launches_per_s=2099
sched.batched.2t,241.05,launches_per_s=4149;mean_width=2.0;speedup=1.98x
sched.modulo.batched.2t,250.00,launches_per_s=4000;mean_width=2.0
"""


def fresh_like(scale=1.0, width=2.0):
    return parse_rows(
        "name,us_per_call,derived\n"
        f"sched.roundrobin.2t,{476.52 * scale:.2f},launches_per_s=1\n"
        f"sched.batched.2t,{241.05 * scale:.2f},mean_width={width}\n"
        f"sched.modulo.batched.2t,{250.0 * scale:.2f},mean_width={width}\n")


def test_parse_rows_roundtrip():
    rows = parse_rows(BASELINE)
    assert set(rows) == {"sched.roundrobin.2t", "sched.batched.2t",
                         "sched.modulo.batched.2t"}
    r = rows["sched.batched.2t"]
    assert r.us_per_call == pytest.approx(241.05)
    assert r.mean_width == 2.0
    assert r.derived["speedup"] == "1.98x"
    assert rows["sched.roundrobin.2t"].mean_width is None


def test_gate_passes_identical_and_faster():
    base = parse_rows(BASELINE)
    assert compare(base, fresh_like(1.0)) == []
    assert compare(base, fresh_like(0.5)) == []       # faster is fine
    assert compare(base, fresh_like(1.2)) == []       # within 25%


def test_gate_fails_on_2x_slowdown():
    """The perf-gate CI job's self-test: --inject-slowdown 2 must fire."""
    base = parse_rows(BASELINE)
    failures = compare(base, fresh_like(2.0))
    assert len(failures) == 3
    assert all("us_per_call regressed" in f for f in failures)


def test_gate_fails_on_mean_width_drop():
    base = parse_rows(BASELINE)
    failures = compare(base, fresh_like(1.0, width=1.0))
    assert len(failures) == 2
    assert all("fusion regression" in f for f in failures)
    # rounding jitter is not a regression
    assert compare(base, fresh_like(1.0, width=1.96)) == []


def test_gate_normalization_absorbs_runner_speed():
    """A uniformly 3x slower runner passes when normalized by the
    round-robin reference row; a *relative* regression still fails."""
    base = parse_rows(BASELINE)
    slow_runner = fresh_like(3.0)
    assert compare(base, slow_runner) != []           # absolute gate fires
    assert compare(base, slow_runner,
                   normalize="sched.roundrobin.2t") == []
    # batched path alone regresses 2x on the same runner -> caught
    skewed = fresh_like(1.0)
    skewed["sched.batched.2t"].us_per_call *= 2
    assert any("sched.batched.2t" in f for f in
               compare(base, skewed, normalize="sched.roundrobin.2t"))


def test_gate_fails_on_disjoint_rows_and_bad_reference():
    base = parse_rows(BASELINE)
    assert compare(base, {}) != []
    other = {"unrelated": Row("unrelated", 1.0, {})}
    assert any("no common rows" in f for f in compare(base, other))
    assert any("missing" in f for f in
               compare(base, fresh_like(1.0), normalize="nope"))


def test_gate_flags_error_rows():
    base = parse_rows("name,us_per_call,derived\nsched.ERROR,0,boom\n")
    fresh = parse_rows("name,us_per_call,derived\nsched.ERROR,0,boom\n")
    assert any("unusable baseline" in f for f in compare(base, fresh))


GATED = """\
name,us_per_call,derived
sched.roundrobin.2t,100.00,launches_per_s=1
fault.detect_latency,2.00,state=quarantined;gate=abs
fault.cotenant.ratio,1.100,within_10pct=True;gate=skip
"""


def test_gate_skip_rows_never_fire():
    """gate=skip rows (higher-is-better ratios) are excluded from the
    us_per_call comparison entirely."""
    base = parse_rows(GATED)
    fresh = parse_rows(GATED.replace("1.100", "9.900"))
    assert compare(base, fresh, normalize="sched.roundrobin.2t") == []


def test_gate_abs_rows_compare_unnormalized():
    """gate=abs rows (deterministic counts — the fault-detection latency)
    ignore the runner-speed normalization: a slow runner never fires
    them, a real latency increase always does."""
    base = parse_rows(GATED)
    # uniformly 3x slower runner: normalized rows absorb it, the abs row
    # is a count and did not change -> pass
    slow = parse_rows(GATED.replace("100.00", "300.00"))
    assert compare(base, slow, normalize="sched.roundrobin.2t") == []
    # latency count doubled on an otherwise identical runner -> fail,
    # even though normalization would have (wrongly) scaled it away if
    # the reference row had also slowed
    worse = parse_rows(GATED.replace("2.00", "4.00")
                            .replace("100.00", "300.00"))
    fails = compare(base, worse, normalize="sched.roundrobin.2t")
    assert any("fault.detect_latency" in f for f in fails)


def test_gate_median_normalization_absorbs_runner_speed():
    """--normalize median: a uniformly slower runner cancels via the
    median fresh/baseline ratio (no single trusted reference row); a
    subset regression still fires because the bulk anchors the median."""
    base = parse_rows(BASELINE)
    assert compare(base, fresh_like(3.0), normalize="median") == []
    skewed = fresh_like(1.0)
    skewed["sched.batched.2t"].us_per_call *= 2
    fails = compare(base, skewed, normalize="median")
    assert any("sched.batched.2t" in f for f in fails)
    # every row is gated under median mode (no spared reference row)
    all_slow = fresh_like(1.0)
    all_slow["sched.roundrobin.2t"].us_per_call *= 2
    assert any("sched.roundrobin.2t" in f
               for f in compare(base, all_slow, normalize="median"))


def test_gate_median_ignores_flagged_rows():
    """gate=skip/abs rows stay out of the median (a huge ratio row must
    not drag the common-mode estimate)."""
    from benchmarks.check_regression import median_ratio

    base = parse_rows(GATED)
    fresh = parse_rows(GATED.replace("1.100", "99.0"))
    assert median_ratio(base, fresh) == pytest.approx(1.0)


def test_trend_csv_reports_ratios():
    base = parse_rows(BASELINE)
    fresh = fresh_like(2.0)
    text = trend_csv(base, fresh, normalize="sched.roundrobin.2t")
    lines = text.strip().splitlines()
    assert lines[0] == "name,baseline_us,fresh_us,ratio,normalized_ratio,gate"
    rows = {ln.split(",")[0]: ln.split(",") for ln in lines[1:]}
    assert set(rows) == {"sched.roundrobin.2t", "sched.batched.2t",
                         "sched.modulo.batched.2t"}
    # raw ratio 2.0, normalized ratio 1.0 (uniform slowdown cancels)
    assert float(rows["sched.batched.2t"][3]) == pytest.approx(2.0)
    assert float(rows["sched.batched.2t"][4]) == pytest.approx(1.0)
    # without a usable reference the normalized column is empty
    text2 = trend_csv(base, fresh, normalize=None)
    assert text2.splitlines()[1].split(",")[4] == ""


# ---------------------------------------------------------------------------
# Cross-push trend history aggregation (benchmarks/aggregate_trend.py)
# ---------------------------------------------------------------------------

TREND_A = """name,baseline_us,fresh_us,ratio,normalized_ratio,gate
sched.batched.2t,100.00,110.00,1.1000,1.0000,
mem.4_clients,0.30,0.30,1.0000,,abs
"""

TREND_B = TREND_A.replace("110.00", "220.00").replace("1.1000", "2.2000")


def test_history_fold_appends_and_labels():
    from benchmarks.aggregate_trend import HEADER, fold, parse_history

    h1 = fold("", TREND_A, "sha1")
    order, rows = parse_history(h1)
    assert h1.splitlines()[0] == HEADER
    assert order == ["sha1"] and len(rows["sha1"]) == 2
    assert rows["sha1"][0].startswith("sha1,sched.batched.2t,")
    h2 = fold(h1, TREND_B, "sha2")
    order, rows = parse_history(h2)
    assert order == ["sha1", "sha2"]
    assert "sha2,sched.batched.2t,100.00,220.00" in h2


def test_history_fold_idempotent_per_label_and_bounded():
    from benchmarks.aggregate_trend import fold, parse_history

    h = fold("", TREND_A, "sha1")
    h = fold(h, TREND_B, "sha1")       # CI retry: replaced, not doubled
    order, rows = parse_history(h)
    assert order == ["sha1"] and len(rows["sha1"]) == 2
    assert "220.00" in h and "110.00" not in h
    # bounded to the most recent `keep` pushes
    for i in range(5):
        h = fold(h, TREND_A, f"sha{i}", keep=3)
    order, _ = parse_history(h)
    assert order == ["sha2", "sha3", "sha4"]
    with pytest.raises(ValueError):
        fold("", TREND_A, "x", keep=0)


# ---------------------------------------------------------------------------
# Sparkline trend report (benchmarks/render_history.py)
# ---------------------------------------------------------------------------

HISTORY = """push,name,baseline_us,fresh_us,ratio,normalized_ratio,gate
sha1,sched.batched.2t,100.00,100.00,1.0000,1.0000,
sha1,mem.4_clients,0.30,0.30,1.0000,1.0000,abs
sha2,sched.batched.2t,100.00,150.00,1.5000,1.4000,
sha3,sched.batched.2t,100.00,120.00,1.2000,1.2000,
sha3,mem.4_clients,0.30,0.30,1.0000,1.0000,abs
garbage line without commas
sha3,bad.ratio,1.0,1.0,1.0,not_a_number,
"""


def test_render_history_parse_and_gaps():
    from benchmarks.render_history import parse_history as ph

    pushes, series = ph(HISTORY)
    assert pushes == ["sha1", "sha2", "sha3"]
    assert set(series) == {"sched.batched.2t", "mem.4_clients"}
    # mem row missing for sha2 -> gap in its series
    assert "sha2" not in series["mem.4_clients"]
    assert series["sched.batched.2t"]["sha2"] == pytest.approx(1.4)


def test_render_history_band_sparkline():
    from benchmarks.render_history import GAP, band_sparkline
    from repro.launch.dashboard import SPARK_CHARS

    s = band_sparkline([1.0, 1.4, None, 1.2])
    assert len(s) == 4 and s[2] == GAP
    assert s[0] == SPARK_CHARS[0]          # band min -> lowest glyph
    assert s[1] == SPARK_CHARS[-1]         # band max -> highest glyph
    # a flat series renders mid-band, not bottomed out
    flat = band_sparkline([1.0, 1.0, 1.0])
    assert flat == SPARK_CHARS[len(SPARK_CHARS) // 2] * 3
    assert band_sparkline([None, None]) == GAP * 2
    assert band_sparkline([]) == ""


def test_render_history_markdown_report():
    from benchmarks.render_history import render_markdown

    md = render_markdown(HISTORY)
    assert "| benchmark | trend |" in md
    assert "`sched.batched.2t`" in md and "`mem.4_clients`" in md
    row = next(ln for ln in md.splitlines() if "sched.batched.2t" in ln)
    # min 1.0, latest 1.2, max 1.4 from the normalized column
    assert "| 1.000 | 1.200 | 1.400 |" in row
    # empty history still renders a valid document
    assert "_(no rows yet)_" in render_markdown("")
