"""CI perf-regression gate (benchmarks/check_regression.py): pure
comparison logic — parsing, thresholds, normalization, width gating, and
the injected-slowdown self-test the perf-gate CI job runs."""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import Row, compare, parse_rows  # noqa: E402

BASELINE = """\
name,us_per_call,derived
sched.roundrobin.2t,476.52,launches_per_s=2099
sched.batched.2t,241.05,launches_per_s=4149;mean_width=2.0;speedup=1.98x
sched.modulo.batched.2t,250.00,launches_per_s=4000;mean_width=2.0
"""


def fresh_like(scale=1.0, width=2.0):
    return parse_rows(
        "name,us_per_call,derived\n"
        f"sched.roundrobin.2t,{476.52 * scale:.2f},launches_per_s=1\n"
        f"sched.batched.2t,{241.05 * scale:.2f},mean_width={width}\n"
        f"sched.modulo.batched.2t,{250.0 * scale:.2f},mean_width={width}\n")


def test_parse_rows_roundtrip():
    rows = parse_rows(BASELINE)
    assert set(rows) == {"sched.roundrobin.2t", "sched.batched.2t",
                         "sched.modulo.batched.2t"}
    r = rows["sched.batched.2t"]
    assert r.us_per_call == pytest.approx(241.05)
    assert r.mean_width == 2.0
    assert r.derived["speedup"] == "1.98x"
    assert rows["sched.roundrobin.2t"].mean_width is None


def test_gate_passes_identical_and_faster():
    base = parse_rows(BASELINE)
    assert compare(base, fresh_like(1.0)) == []
    assert compare(base, fresh_like(0.5)) == []       # faster is fine
    assert compare(base, fresh_like(1.2)) == []       # within 25%


def test_gate_fails_on_2x_slowdown():
    """The perf-gate CI job's self-test: --inject-slowdown 2 must fire."""
    base = parse_rows(BASELINE)
    failures = compare(base, fresh_like(2.0))
    assert len(failures) == 3
    assert all("us_per_call regressed" in f for f in failures)


def test_gate_fails_on_mean_width_drop():
    base = parse_rows(BASELINE)
    failures = compare(base, fresh_like(1.0, width=1.0))
    assert len(failures) == 2
    assert all("fusion regression" in f for f in failures)
    # rounding jitter is not a regression
    assert compare(base, fresh_like(1.0, width=1.96)) == []


def test_gate_normalization_absorbs_runner_speed():
    """A uniformly 3x slower runner passes when normalized by the
    round-robin reference row; a *relative* regression still fails."""
    base = parse_rows(BASELINE)
    slow_runner = fresh_like(3.0)
    assert compare(base, slow_runner) != []           # absolute gate fires
    assert compare(base, slow_runner,
                   normalize="sched.roundrobin.2t") == []
    # batched path alone regresses 2x on the same runner -> caught
    skewed = fresh_like(1.0)
    skewed["sched.batched.2t"].us_per_call *= 2
    assert any("sched.batched.2t" in f for f in
               compare(base, skewed, normalize="sched.roundrobin.2t"))


def test_gate_fails_on_disjoint_rows_and_bad_reference():
    base = parse_rows(BASELINE)
    assert compare(base, {}) != []
    other = {"unrelated": Row("unrelated", 1.0, {})}
    assert any("no common rows" in f for f in compare(base, other))
    assert any("missing" in f for f in
               compare(base, fresh_like(1.0), normalize="nope"))


def test_gate_flags_error_rows():
    base = parse_rows("name,us_per_call,derived\nsched.ERROR,0,boom\n")
    fresh = parse_rows("name,us_per_call,derived\nsched.ERROR,0,boom\n")
    assert any("unusable baseline" in f for f in compare(base, fresh))
