"""End-to-end behaviour tests: training convergence + restart-exactness,
multi-tenant serving isolation, fence-mode equivalence for honest
workloads."""

import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fence import FencePolicy
from repro.models import get_model


def test_training_reduces_loss(tmp_path):
    """50 steps of the real train driver on a reduced config learns the
    synthetic grammar."""
    from repro.launch import train as T
    argv = sys.argv
    sys.argv = ["train", "--arch", "stablelm-3b", "--reduced",
                "--steps", "50", "--batch", "4", "--seq", "64",
                "--lr", "5e-3", "--log-every", "100"]
    try:
        summary = T.main()
    finally:
        sys.argv = argv
    assert summary["final_loss"] < summary["first_loss"] - 0.3


def test_training_restart_exact(tmp_path):
    """Checkpoint at step 20, restart, arrive at the same step-40 params
    as an uninterrupted run (fault-tolerance contract)."""
    from repro.data import DataConfig, SyntheticLM
    from repro.checkpoint import CheckpointStore
    from repro.optim import adamw, apply_updates, constant

    cfg = get_config("minicpm-2b").reduced()
    api = get_model(cfg)
    opt = adamw(constant(1e-3))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, seed=0))

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch, remat=False))(params)
        u, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, u), opt_state, loss

    def run(start, stop, params, opt_state):
        for s in range(start, stop):
            batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            params, opt_state, _ = step_fn(params, opt_state, batch)
        return params, opt_state

    p0 = api.init(jax.random.PRNGKey(0))
    s0 = opt.init(p0)
    # uninterrupted 0..40
    pA, sA = run(0, 40, p0, s0)
    # interrupted at 20 with checkpoint roundtrip
    pB, sB = run(0, 20, p0, s0)
    store = CheckpointStore(str(tmp_path))
    store.save(20, (pB, sB))
    (pB, sB), step = store.restore((pB, sB))
    assert step == 20
    pB, sB = run(20, 40, pB, sB)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_fence_modes_equivalent_for_honest_tenant():
    """For in-partition workloads, BITWISE / MODULO / CHECK / native all
    produce identical losses — the fences are semantic no-ops (§4.4)."""
    from repro.launch.steps import make_guard
    from repro.configs import ShapeConfig

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab)
    shape = ShapeConfig("t", "train", 32, 2)
    losses = {}
    for name, policy, enabled in [
            ("native", FencePolicy.BITWISE, False),
            ("bitwise", FencePolicy.BITWISE, True),
            ("modulo", FencePolicy.MODULO, True),
            ("check", FencePolicy.CHECK, True)]:
        guard = make_guard(cfg, shape, policy, enabled)
        losses[name] = float(api.loss(params, {"tokens": toks},
                                      guard=guard, remat=False))
    base = losses["native"]
    for name, v in losses.items():
        assert abs(v - base) < 1e-5, losses


def test_serve_engine_multi_tenant_isolation():
    """Two tenants share the engine; tenant B's requests do not perturb
    tenant A's generations (vs A running alone)."""
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(0)
    prompt_a = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
    prompt_b = rng.integers(0, cfg.vocab, size=12).astype(np.int32)

    # A alone
    eng1 = ServeEngine(cfg, max_batch=4, max_len=128)
    eng1.register_tenant("a", 2)
    rid_a1 = eng1.submit("a", prompt_a)
    out1 = eng1.run(max_new_tokens=8)[rid_a1]

    # A + B co-located
    eng2 = ServeEngine(cfg, max_batch=4, max_len=128)
    eng2.register_tenant("a", 2)
    eng2.register_tenant("b", 2)
    rid_a2 = eng2.submit("a", prompt_a)
    rid_b = eng2.submit("b", prompt_b)
    out2 = eng2.run(max_new_tokens=8)
    assert out2[rid_a2] == out1, "tenant B perturbed tenant A"


def test_serve_guard_blocks_forged_slots():
    """A forged slot id (scheduler compromise) wraps inside the owner's
    partition: the victim tenant's cache rows stay untouched."""
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=8, max_len=128)
    vp = eng.register_tenant("victim", 4)
    eng.register_tenant("attacker", 4)
    rng = np.random.default_rng(1)
    rid_v = eng.submit("victim", rng.integers(0, cfg.vocab, 8))
    eng.run(max_new_tokens=2)
    sl = slice(vp.base, vp.base + vp.size)
    victim_rows = np.asarray(eng.cache.k[:, sl]).copy()
    assert (victim_rows != 0).any()   # victim actually wrote its slots

    # attacker submits; then we forge its slot to point at the victim
    rid_a = eng.submit("attacker",
                       rng.integers(0, cfg.vocab, 8).astype(np.int32))
    req = [r for r in eng._requests if r.rid == rid_a][0]
    req.slot = vp.base   # forged: victim's slot!
    eng.run(max_new_tokens=2)
    # fence wrapped the write into the attacker's own partition:
    after = np.asarray(eng.cache.k[:, sl])
    np.testing.assert_array_equal(victim_rows, after)


def test_serve_jit_steps_bit_identical_to_eager():
    """The compiled trusted-step path (jit_steps=True, the default) and
    the eager fallback (--no-jit) produce bit-identical generations —
    the tentpole's correctness contract for jitting the serving hot
    path."""
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(5)
    prompts = {t: rng.integers(0, cfg.vocab, 10, np.int32)
               for t in ("a", "b")}
    outs = []
    for jit in (True, False):
        eng = ServeEngine(cfg, max_batch=4, max_len=64, jit_steps=jit)
        rids = {}
        for t, p in prompts.items():
            eng.register_tenant(t, 2)
            rids[t] = eng.submit(t, p)
        out = eng.run(max_new_tokens=6)
        outs.append({t: out[r] for t, r in rids.items()})
        # the jitted engine compiled its steps; the eager one never did
        entry = eng.manager.pointer_to_symbol[eng._steps.decode_name]
        assert any(k[0] == "trusted" for k in entry.jit_cache) == jit
    assert outs[0] == outs[1]


def test_multi_engine_fused_decode_matches_solo():
    """Two engines sharing one GuardianManager: each lockstep drain fuses
    the engines' steps into ONE compiled device step (width 2), and every
    engine's generations are bit-identical to running it solo on its own
    manager — fusion changes dispatch, never semantics."""
    from repro.launch.serve import (
        ServeEngine,
        make_shared_manager,
        serve_engines,
    )

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, 10, np.int32) for _ in range(2)]
    tokens = 5

    solo_outs = []
    for i, prompt in enumerate(prompts):
        eng = ServeEngine(cfg, max_batch=4, max_len=64)
        eng.register_tenant(f"t{i}", 2)
        rid = eng.submit(f"t{i}", prompt)
        solo_outs.append(eng.run(max_new_tokens=tokens)[rid])

    mgr = make_shared_manager(2, max_batch=4)
    engines = [ServeEngine(cfg, max_batch=4, max_len=64, manager=mgr)
               for _ in range(2)]
    rids = []
    for i, (eng, prompt) in enumerate(zip(engines, prompts)):
        eng.register_tenant(f"t{i}", 2)
        rids.append(eng.submit(f"t{i}", prompt))
    outs = serve_engines(engines, max_new_tokens=tokens)

    for i in range(2):
        assert outs[i][rids[i]] == solo_outs[i], f"engine {i} perturbed"
    st = mgr.scheduler.stats
    # 1 prefill + `tokens` decodes, every lockstep fused at width 2
    assert st.fused_steps == 1 + tokens
    assert st.mean_batch_width == 2.0
    assert st.single_steps == 0
    # both engines share one symbol entry (same model fingerprint)
    assert engines[0]._steps.decode_name == engines[1]._steps.decode_name


def test_multi_engine_quarantine_stays_scoped():
    """Quarantining a tenant of one co-hosted engine drops only that
    engine's requests; the sibling engine keeps serving through the same
    shared manager."""
    from repro.launch.serve import (
        ServeEngine,
        make_shared_manager,
        serve_engines,
    )

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(7)
    mgr = make_shared_manager(2, max_batch=4)
    engines = [ServeEngine(cfg, max_batch=4, max_len=64, manager=mgr)
               for _ in range(2)]
    rids = []
    for i, eng in enumerate(engines):
        eng.register_tenant(f"t{i}", 2)
        rids.append(eng.submit(f"t{i}",
                               rng.integers(0, cfg.vocab, 8, np.int32)))
    dropped = engines[0].quarantine_tenant("t0", reason="abuse")
    assert dropped == [rids[0]]
    outs = serve_engines(engines, max_new_tokens=3)
    assert outs[0] == {}                      # engine 0 had nothing left
    assert rids[1] in outs[1] and len(outs[1][rids[1]]) == 3


def test_dryrun_cli_single_cell(tmp_path):
    """The dry-run entrypoint runs standalone for a small arch."""
    import os
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm-350m", "--shape", "decode_32k", "--out-dir",
         str(tmp_path)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_serve_pool_resizes_with_tenant():
    """Elastic serving: a tenant out of KV slots grows through the
    elastic control plane at submit time — the pool partition doubles
    (relocating if the buddy is taken), its existing requests re-address,
    and generations match a tenant that was sized big enough up front."""
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, 10, np.int32) for _ in range(3)]

    def run(initial_slots):
        eng = ServeEngine(cfg, max_batch=8, max_len=64)
        eng.register_tenant("a", initial_slots)
        eng.register_tenant("b", 2)
        rids = [eng.submit("a", p) for p in prompts]
        out = eng.run(max_new_tokens=4)
        return [out[r] for r in rids], eng

    small, eng = run(2)          # 3rd submit forces a grow
    big, _ = run(4)              # pre-sized control
    assert small == big
    part = eng.manager.bounds.lookup("a")
    assert part.size == 4
    assert any(e.startswith("grow a") for e in eng.manager.elastic.events)
