"""Quarantine lifecycle + CHECK-mode fused launches with selective commit
(DESIGN.md §Fault-containment).

Covers the acceptance scenario end-to-end: a fused CHECK drain with one OOB
tenant commits co-tenant rows byte-identically to their standalone runs,
rolls the offender back, logs its row, quarantines it past the threshold
while co-tenants continue, and eviction reclaims + scrubs the partition and
purges the symbol caches.

State-machine invariants run as a deterministic sweep over all transition
pairs plus a hypothesis property mirror over random transition sequences
(tests/_hyp.py convention): no transition out of EVICTED except explicit
re-admission.
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import (
    FencePolicy,
    GuardianManager,
    QuarantineError,
    QuarantineStateMachine,
    SharingMode,
    TenantQuarantined,
    TenantState,
    ThresholdPolicy,
)


def bump(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def evil_write(arena, target, n):
    idx = target + jnp.arange(n, dtype=jnp.int32)
    return arena.at[idx].set(999.0), None


def make_manager(n=3, slots=512, **kw):
    kw.setdefault("policy", FencePolicy.CHECK)
    mgr = GuardianManager(total_slots=slots, **kw)
    clients = []
    for i in range(n):
        c = mgr.register_tenant(f"t{i}", slots // (2 * n))
        c.module_load("bump", bump)
        c.module_load("evil", evil_write)
        clients.append(c)
    return mgr, clients


# ---------------------------------------------------------------------------
# State machine: transition legality
# ---------------------------------------------------------------------------

_OPS = ("quarantine", "evict", "readmit")

# the complete legal transition relation (op applied in state -> new state)
_LEGAL_SWEEP = {
    (TenantState.ACTIVE, "quarantine"): TenantState.QUARANTINED,
    (TenantState.READMITTED, "quarantine"): TenantState.QUARANTINED,
    (TenantState.QUARANTINED, "evict"): TenantState.EVICTED,
    (TenantState.QUARANTINED, "readmit"): TenantState.READMITTED,
    (TenantState.EVICTED, "readmit"): TenantState.READMITTED,
}


def _machine_in(state: TenantState) -> QuarantineStateMachine:
    m = QuarantineStateMachine()
    m.admit("t")
    path = {
        TenantState.ACTIVE: (),
        TenantState.QUARANTINED: ("quarantine",),
        TenantState.EVICTED: ("quarantine", "evict"),
        TenantState.READMITTED: ("quarantine", "readmit"),
    }[state]
    for op in path:
        getattr(m, op)("t")
    return m


def test_transition_table_sweep():
    """Every (state, op) pair behaves per the legal-transition relation."""
    for state, op in itertools.product(TenantState, _OPS):
        m = _machine_in(state)
        want = _LEGAL_SWEEP.get((state, op))
        if want is None:
            with pytest.raises(QuarantineError):
                getattr(m, op)("t")
            assert m.state_of("t") is state      # illegal op is a no-op
        else:
            getattr(m, op)("t")
            assert m.state_of("t") is want


def test_no_exit_from_evicted_except_readmit():
    m = _machine_in(TenantState.EVICTED)
    with pytest.raises(QuarantineError):
        m.quarantine("t")
    with pytest.raises(QuarantineError):
        m.evict("t")
    with pytest.raises(QuarantineError):
        m.admit("t")                 # re-registration is not an exit
    assert m.state_of("t") is TenantState.EVICTED
    m.readmit("t")                   # the single legal exit
    assert m.state_of("t") is TenantState.READMITTED


def test_eviction_record_survives_forget():
    m = _machine_in(TenantState.EVICTED)
    m.forget("t")                    # teardown must not launder the ban
    assert m.state_of("t") is TenantState.EVICTED
    m2 = _machine_in(TenantState.ACTIVE)
    m2.forget("t")
    assert m2.state_of("t") is None  # healthy teardown does forget


@given(st.lists(st.sampled_from(_OPS), min_size=1, max_size=12))
@settings(max_examples=60, deadline=None)
def test_random_walk_respects_transition_table(ops):
    """Property mirror of the sweep: under any op sequence the machine
    only ever moves along legal edges, and EVICTED is only ever left via
    an explicit readmit."""
    m = QuarantineStateMachine()
    m.admit("t")
    state = TenantState.ACTIVE
    for op in ops:
        want = _LEGAL_SWEEP.get((state, op))
        if want is None:
            with pytest.raises(QuarantineError):
                getattr(m, op)("t")
        else:
            if state is TenantState.EVICTED:
                assert op == "readmit"
            getattr(m, op)("t")
            state = want
        assert m.state_of("t") is state


def test_quarantine_counters():
    m = QuarantineStateMachine()
    m.admit("t")
    m.quarantine("t", reason="r1")
    m.readmit("t")
    m.quarantine("t", reason="r2")
    rec = m.record_of("t")
    assert rec.quarantines == 2 and rec.readmissions == 1


# ---------------------------------------------------------------------------
# Fused CHECK drain: per-row ok + selective commit (acceptance scenario)
# ---------------------------------------------------------------------------


def test_fused_check_selective_commit_matches_standalone():
    """Co-tenants' writes in a fused CHECK step with an OOB offender are
    byte-identical to their standalone runs; the offender's writes never
    land; its ViolationLog row is non-zero."""
    # standalone reference: each well-behaved tenant alone, same launches
    refs = {}
    for i in range(2):
        mgr, clients = make_manager(
            3, quarantine_policy=ThresholdPolicy(quarantine_after=1 << 30))
        c = clients[i]
        p = c.malloc(8)
        c.memcpy_h2d(p, np.arange(8, dtype=np.float32))
        for _ in range(3):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
        mgr.synchronize()
        part = mgr.bounds.lookup(f"t{i}")
        refs[i] = np.asarray(
            mgr.arena.unsafe_read_range(part.base, part.size)).copy()

    # fused run: t0, t1 behave; t2 launches the SAME kernel with a forged
    # pointer into t0 — all three rows ride in one fused CHECK step
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=1 << 30))
    ptrs = []
    for c in clients[:2]:
        p = c.malloc(8)
        c.memcpy_h2d(p, np.arange(8, dtype=np.float32))
        ptrs.append(p)
    mgr.synchronize()                       # uploads land before cycle 1
    victim = mgr.bounds.lookup("t0")
    for _ in range(3):
        for c, p in zip(clients[:2], ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
        clients[2].launch_kernel(           # forged ptr, same signature
            "bump", args=(jnp.int32(victim.base), 8))
    mgr.synchronize()
    assert mgr.scheduler.stats.fused_steps == 3      # all 3 rows fused
    assert mgr.scheduler.stats.check_steps == 3
    assert list(mgr.scheduler.stats.batch_widths) == [3, 3, 3]

    for i in range(2):
        part = mgr.bounds.lookup(f"t{i}")
        got = np.asarray(mgr.arena.unsafe_read_range(part.base, part.size))
        np.testing.assert_array_equal(got, refs[i], err_msg=f"t{i}")
    # offender: no write landed anywhere (its own partition stays zero,
    # and t0's bytes above already matched the attack-free reference)
    part2 = mgr.bounds.lookup("t2")
    own = np.asarray(mgr.arena.unsafe_read_range(part2.base, part2.size))
    assert (own == 0).all()
    # attribution: 3 launches x 8 OOB gather + 8 OOB scatter elements
    assert mgr.violog.counts("t2") == {
        "gather": 24, "scatter": 24, "slice": 0, "update": 0}
    assert mgr.violog.total("t0") == 0 and mgr.violog.total("t1") == 0


def test_threshold_quarantines_offender_cotenants_uninterrupted():
    """Crossing the threshold mid-drain drops the offender's remaining ops
    while co-tenant launches in the same drain keep landing."""
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=16))
    ptrs = []
    for c in clients[:2]:
        p = c.malloc(8)
        c.memcpy_h2d(p, np.zeros(8, np.float32))
        ptrs.append(p)
    victim = mgr.bounds.lookup("t0")
    cycles = 6
    for _ in range(cycles):
        for c, p in zip(clients[:2], ptrs):
            c.launch_kernel("bump", ptrs=[p], args=(8,))
        clients[2].launch_kernel("evil", args=(jnp.int32(victim.base), 8))
    mgr.synchronize()
    # 8 violations/launch -> quarantined after the 2nd offending cycle
    assert mgr.quarantine.state_of("t2") is TenantState.QUARANTINED
    assert mgr.violog.total("t2") == 16          # later ops were dropped
    # every co-tenant cycle still landed
    for c, p in zip(clients[:2], ptrs):
        np.testing.assert_array_equal(
            c.memcpy_d2h(p, 8), np.full(8, float(cycles), np.float32))
    with pytest.raises(TenantQuarantined):
        clients[2].launch_kernel("bump", args=(jnp.int32(0), 8))
    report = mgr.violation_report()
    assert report["tenants"]["t2"]["state"] == "quarantined"
    assert report["events"]


def test_eviction_scrubs_reclaims_and_bans():
    mgr, clients = make_manager(
        2, quarantine_policy=ThresholdPolicy(quarantine_after=8))
    part = mgr.bounds.lookup("t1")
    p = clients[1].malloc(8)
    clients[1].memcpy_h2d(p, np.full(8, 5.0, np.float32))
    clients[1].launch_kernel(
        "evil", args=(jnp.int32(mgr.bounds.lookup("t0").base), 8))
    mgr.synchronize()
    assert mgr.quarantine.state_of("t1") is TenantState.QUARANTINED
    free_before = mgr.bounds.free_slots()
    mgr.quarantine.evict("t1")
    # partition scrubbed and returned to the buddy allocator
    got = np.asarray(mgr.arena.unsafe_read_range(part.base, part.size))
    assert (got == 0).all()
    assert mgr.bounds.free_slots() == free_before + part.size
    # final counts survive in the report after the log row was recycled
    rep = mgr.violation_report()["tenants"]["t1"]
    assert rep["state"] == "evicted" and rep["scatter"] == 8
    # the ban holds across re-registration attempts...
    with pytest.raises(QuarantineError):
        mgr.register_tenant("t1", 64)
    # ...until explicit re-admission
    mgr.quarantine.readmit("t1")
    c_new = mgr.register_tenant("t1", 64)
    assert mgr.bounds.lookup("t1").base == part.base   # freed block reused
    assert c_new is mgr._clients["t1"]


def test_remove_tenant_cannot_launder_quarantine():
    """Voluntary teardown of a QUARANTINED tenant is refused — otherwise
    remove + re-register would yield a fresh ACTIVE record with zeroed
    counters."""
    mgr, clients = make_manager(
        2, quarantine_policy=ThresholdPolicy(quarantine_after=8))
    clients[1].launch_kernel(
        "evil", args=(jnp.int32(mgr.bounds.lookup("t0").base), 8))
    mgr.synchronize()
    assert mgr.quarantine.state_of("t1") is TenantState.QUARANTINED
    with pytest.raises(QuarantineError):
        mgr.remove_tenant("t1")
    assert mgr.quarantine.state_of("t1") is TenantState.QUARANTINED
    assert mgr.violog.total("t1") == 8           # counters intact
    # healthy co-tenant teardown still works
    mgr.remove_tenant("t0")
    assert mgr.quarantine.state_of("t0") is None


def test_readmit_from_quarantine_restores_service_and_counters():
    mgr, clients = make_manager(
        2, quarantine_policy=ThresholdPolicy(quarantine_after=8))
    clients[1].launch_kernel(
        "evil", args=(jnp.int32(mgr.bounds.lookup("t0").base), 8))
    mgr.synchronize()
    assert mgr.quarantine.state_of("t1") is TenantState.QUARANTINED
    mgr.quarantine.readmit("t1")
    assert mgr.quarantine.state_of("t1") is TenantState.READMITTED
    assert mgr.violog.total("t1") == 0           # slate wiped
    p = clients[1].malloc(4)                     # partition survived
    clients[1].memcpy_h2d(p, np.ones(4, np.float32))
    clients[1].launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    np.testing.assert_array_equal(clients[1].memcpy_d2h(p, 4),
                                  np.full(4, 2.0, np.float32))


# ---------------------------------------------------------------------------
# Symbol-cache eviction (ROADMAP: "eviction policy for the symbol caches")
# ---------------------------------------------------------------------------


def test_remove_tenant_evicts_native_jit_entries():
    """A removed tenant's cached unfenced (NONE-policy) binary can never be
    launched again: the native entries leave the per-kernel jit caches on
    remove_tenant, and the next tenant set compiles fresh fenced twins."""
    mgr = GuardianManager(total_slots=256)
    solo = mgr.register_tenant("solo", 64)
    solo.module_load("bump", bump)
    p = solo.malloc(8)
    solo.memcpy_h2d(p, np.zeros(8, np.float32))
    solo.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    entry = mgr.pointer_to_symbol["bump"]
    assert any(k[0] == "native" for k in entry.jit_cache)
    mgr.remove_tenant("solo")
    assert not any(k[0] == "native" for k in entry.jit_cache)

    # a new pair of tenants reuses the symbol; nothing native remains
    a = mgr.register_tenant("a", 64)
    mgr.register_tenant("b", 64)
    pa = a.malloc(8)
    a.memcpy_h2d(pa, np.zeros(8, np.float32))
    a.launch_kernel("bump", ptrs=[pa], args=(8,))
    mgr.synchronize()
    assert not any(k[0] == "native" for k in entry.jit_cache)


def test_quarantine_evicts_native_jit_entries():
    mgr = GuardianManager(
        total_slots=256, policy=FencePolicy.BITWISE,
        quarantine_policy=ThresholdPolicy(quarantine_after=1 << 30))
    solo = mgr.register_tenant("solo", 64)
    solo.module_load("bump", bump)
    p = solo.malloc(8)
    solo.memcpy_h2d(p, np.zeros(8, np.float32))
    solo.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.synchronize()
    entry = mgr.pointer_to_symbol["bump"]
    assert any(k[0] == "native" for k in entry.jit_cache)
    mgr.quarantine.quarantine("solo", reason="operator action")
    assert not any(k[0] == "native" for k in entry.jit_cache)


def test_eviction_purges_modulo_and_table_caches():
    mgr, clients = make_manager(
        2, policy=FencePolicy.MODULO, mode=SharingMode.TIME_SHARE,
        quarantine_policy=ThresholdPolicy(quarantine_after=1 << 30))
    part = mgr.bounds.lookup("t1")
    p = clients[1].malloc(4)
    clients[1].memcpy_h2d(p, np.ones(4, np.float32))
    clients[1].launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.synchronize()
    entry = mgr.pointer_to_symbol["bump"]
    key = (part.base, part.size)
    assert key in entry.modulo_static
    mgr.quarantine.quarantine("t1")
    mgr.quarantine.evict("t1")
    assert key not in entry.modulo_static
    assert not any(k[0] == f"mod{part.base}.{part.size}"
                   for k in entry.jit_cache)


# ---------------------------------------------------------------------------
# Serving plane
# ---------------------------------------------------------------------------


def test_serve_rejects_and_reroutes_quarantined_tenant():
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=4, max_len=64)
    eng.register_tenant("good", 2)
    eng.register_tenant("bad", 2)
    rng = np.random.default_rng(0)
    rid_g = eng.submit("good", rng.integers(0, cfg.vocab, 8, np.int32))
    rid_b = eng.submit("bad", rng.integers(0, cfg.vocab, 8, np.int32))
    dropped = eng.quarantine_tenant("bad", reason="abuse signal")
    assert dropped == [rid_b]
    with pytest.raises(TenantQuarantined):
        eng.submit("bad", rng.integers(0, cfg.vocab, 8, np.int32))
    out = eng.run(max_new_tokens=2)
    assert rid_g in out and rid_b not in out     # good tenant re-routed in
    # eviction frees the pool partition for a newcomer
    bad_part = eng.bounds.lookup("bad")
    eng.evict_tenant("bad")
    eng.register_tenant("new", 2)
    assert eng.bounds.lookup("new").base == bad_part.base
    with pytest.raises(QuarantineError):
        eng.register_tenant("bad", 2)            # ban survives eviction


def test_serve_steps_ride_the_shared_scheduler():
    """The unified launch path: every prefill/decode step is a
    LaunchRequest drained by the manager's BatchedLaunchScheduler under
    the engine tenant; the engine owns no fence table or row-assignment
    logic of its own."""
    from repro.configs import get_config
    from repro.launch.serve import ENGINE_TENANT, ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=4, max_len=64)
    eng.register_tenant("a", 2)
    rng = np.random.default_rng(0)
    eng.submit("a", rng.integers(0, cfg.vocab, 8, np.int32))
    out = eng.run(max_new_tokens=3)
    assert len(out) == 1
    st = eng.manager.scheduler.stats
    # 1 prefill + 3 decode steps, all via the scheduler's per-launch path
    assert st.single_steps == 4 and st.total_launches == 4
    assert all(b == (ENGINE_TENANT,)
               for b in eng.manager.scheduler.dispatch_log)
    # the engine delegates fencing rows to the manager
    assert not hasattr(eng, "_fence_table")
    assert not hasattr(eng, "_assign_rows")
    table, row_of = eng.manager.fence_table()
    assert set(row_of) == {ENGINE_TENANT, "a"} and table.magic is not None
    # and its step launches appear in the client-side call trace
    assert eng._client.trace.api_counts()["cudaLaunchKernel"] == 4


def test_manager_side_quarantine_propagates_to_serve_engine():
    """A quarantine decided on the manager side (not via the engine API)
    drops the tenant's pending serve requests and blocks submission —
    the transition subscription closes the loop."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=4, max_len=64)
    eng.register_tenant("good", 2)
    eng.register_tenant("rogue", 2)
    rng = np.random.default_rng(1)
    rid_g = eng.submit("good", rng.integers(0, cfg.vocab, 8, np.int32))
    rid_r = eng.submit("rogue", rng.integers(0, cfg.vocab, 8, np.int32))
    # manager-side decision (e.g. threshold crossing from raw launches)
    eng.manager.quarantine.quarantine("rogue", reason="violog threshold")
    assert rid_r in eng.rejected
    with pytest.raises(TenantQuarantined):
        eng.submit("rogue", rng.integers(0, cfg.vocab, 8, np.int32))
    out = eng.run(max_new_tokens=2)
    assert rid_g in out and rid_r not in out
    # eviction through the manager scrubs the serve pool slots
    part = eng.bounds.lookup("rogue")
    eng.manager.quarantine.evict("rogue")
    sl = np.asarray(eng.cache.k[:, part.base:part.base + part.size])
    assert (sl == 0).all()
    assert any("quarantine rogue" in e for e in eng.manager.quarantine.events)


def test_serve_check_rows_attribute_and_threshold_quarantine():
    """A CHECK tenant spraying out-of-partition slot ids is detected by
    the serving plane, attributed to the manager's ViolationLog, and
    quarantined by the same threshold poll that polices raw launches;
    co-tenants keep generating."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=8, max_len=64,
                      quarantine_policy=ThresholdPolicy(quarantine_after=3))
    eng.register_tenant("honest", 2)
    vp = eng.register_tenant("victim", 2)
    eng.register_tenant("sprayer", 2, policy=FencePolicy.CHECK)
    rng = np.random.default_rng(2)
    rid_h = eng.submit("honest", rng.integers(0, cfg.vocab, 8, np.int32))
    eng.submit("victim", rng.integers(0, cfg.vocab, 8, np.int32))
    rid_s = eng.submit("sprayer", rng.integers(0, cfg.vocab, 8, np.int32))
    # forge the sprayer's slot into the victim's partition
    req = [r for r in eng._requests if r.rid == rid_s][0]
    req.slot = vp.base
    out = eng.run(max_new_tokens=4)
    assert rid_h in out and len(out[rid_h]) == 4
    assert eng.manager.violog.total("sprayer") >= 3
    assert eng.manager.quarantine.state_of("sprayer") is \
        TenantState.QUARANTINED
    with pytest.raises(TenantQuarantined):
        eng.submit("sprayer", rng.integers(0, cfg.vocab, 8, np.int32))


def test_serve_mid_run_eviction_scrubs_final_cache_and_drops_output():
    """Auto-eviction firing *during* run() (threshold poll between decode
    steps) must survive the run-end cache commit: the evicted tenant's
    pool slots are zero in the final cache, its rid is rejected — not
    served — and co-tenants finish unharmed (regression: the scrub used
    to be overwritten by the stale local cache, and attribution crashed
    on the reclaimed tenant)."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    eng = ServeEngine(cfg, max_batch=8, max_len=64,
                      policy=FencePolicy.CHECK,
                      quarantine_policy=ThresholdPolicy(
                          quarantine_after=2, evict_after=2))
    eng.register_tenant("honest", 2)
    vp = eng.register_tenant("victim", 2)
    sp = eng.register_tenant("sprayer", 2)
    rng = np.random.default_rng(4)
    rid_h = eng.submit("honest", rng.integers(0, cfg.vocab, 8, np.int32))
    rid_s = eng.submit("sprayer", rng.integers(0, cfg.vocab, 8, np.int32))
    req = [r for r in eng._requests if r.rid == rid_s][0]
    req.slot = vp.base                      # forged into the victim
    out = eng.run(max_new_tokens=6)
    assert eng.manager.quarantine.state_of("sprayer") is TenantState.EVICTED
    assert rid_h in out and len(out[rid_h]) == 6
    assert rid_s not in out and rid_s in eng.rejected
    # the evicted tenant's partition is scrubbed in the COMMITTED cache
    sl = np.asarray(eng.cache.k[:, sp.base:sp.base + sp.size])
    assert (sl == 0).all()
    # and the freed block serves a newcomer without inheriting data
    assert eng.register_tenant("newcomer", 2).base == sp.base


def test_per_tenant_none_policy_override_refused():
    """A NONE per-tenant override would run unfenced beside co-tenants —
    the manager refuses it at registration (the native fast path is only
    ever granted, and revoked, by the standalone check)."""
    mgr = GuardianManager(total_slots=256)
    with pytest.raises(ValueError):
        mgr.register_tenant("evil", 32, policy=FencePolicy.NONE)
    assert mgr.quarantine.machine.record_of("evil") is None  # no leak


def test_serve_mixed_policies_match_homogeneous_for_honest_tenants():
    """Row-mixed fencing (MODULO + CHECK tenants beside the BITWISE
    default) is a semantic no-op for in-partition workloads: generations
    are bit-identical to the all-BITWISE engine."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(3)
    prompts = {t: rng.integers(0, cfg.vocab, 8, np.int32)
               for t in ("a", "b", "c")}
    outs = []
    for policies in ({}, {"a": FencePolicy.MODULO,
                          "b": FencePolicy.CHECK}):
        eng = ServeEngine(cfg, max_batch=8, max_len=64)
        rids = {}
        for t, p in prompts.items():
            eng.register_tenant(t, 2, policy=policies.get(t))
            rids[t] = eng.submit(t, p)
        out = eng.run(max_new_tokens=4)
        outs.append({t: out[r] for t, r in rids.items()})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Automatic readmission probes (probation partitions)
# ---------------------------------------------------------------------------


def _quarantine_rogue(mgr, clients, threshold=4):
    """Drive the last client over the CHECK threshold; returns its id."""
    rogue = clients[-1]
    outside = jnp.int32(mgr.bounds.total_slots - 8)
    while mgr.quarantine.state_of(rogue.tenant_id).admissible:
        rogue.launch_kernel("evil", args=(outside, 8))
        mgr.run_queued()
    return rogue.tenant_id


def test_probe_readmits_after_n_clean_cycles_into_probation():
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=4),
        readmit_after=3)
    good, rogue_id = clients[0], _quarantine_rogue(mgr, clients)
    assert mgr.quarantine.state_of(rogue_id) is TenantState.QUARANTINED
    big_before = mgr.bounds.lookup(rogue_id).size
    # clean cycles: only the good tenant drains; the probe clock advances
    p = good.malloc(4)
    good.memcpy_h2d(p, np.zeros(4, np.float32))
    for _ in range(3):
        good.launch_kernel("bump", ptrs=[p], args=(4,))
        mgr.run_queued()
    rec = mgr.quarantine.machine.record_of(rogue_id)
    assert rec.state is TenantState.READMITTED
    assert rec.probation
    assert any(e.startswith(f"probe-readmit {rogue_id}")
               for e in mgr.quarantine.events)
    # probation partition sized by the admission controller (live span
    # is 0 -> the policy floor), smaller than the original reservation
    part = mgr.bounds.lookup(rogue_id)
    assert part.size == mgr.elastic.probation_slots_for(rogue_id)
    assert part.size < big_before
    # counters were wiped; the tenant serves again
    assert mgr.violog.total(rogue_id) == 0
    clients[-1].launch_kernel("bump", ptrs=[clients[-1].malloc(2)],
                              args=(2,))
    mgr.run_queued()


def test_probation_violation_evicts_on_first_offense():
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=4),
        readmit_after=1)
    good, rogue_id = clients[0], _quarantine_rogue(mgr, clients)
    # one clean cycle -> probe readmission
    p = good.malloc(4)
    good.memcpy_h2d(p, np.zeros(4, np.float32))
    good.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.run_queued()
    assert mgr.quarantine.machine.record_of(rogue_id).probation
    # a single violation on probation evicts — no second threshold
    outside = jnp.int32(mgr.bounds.total_slots - 8)
    clients[-1].launch_kernel("evil", args=(outside, 8))
    mgr.run_queued()
    assert mgr.quarantine.state_of(rogue_id) is TenantState.EVICTED
    # and the ban sticks: re-registration is refused
    with pytest.raises(QuarantineError):
        mgr.register_tenant(rogue_id, 8)


def test_manual_readmit_clears_probation():
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=4),
        readmit_after=1)
    good, rogue_id = clients[0], _quarantine_rogue(mgr, clients)
    p = good.malloc(4)
    good.memcpy_h2d(p, np.zeros(4, np.float32))
    good.launch_kernel("bump", ptrs=[p], args=(4,))
    mgr.run_queued()
    rec = mgr.quarantine.machine.record_of(rogue_id)
    assert rec.probation
    # an operator quarantine + readmit is an explicit trust statement
    mgr.quarantine.quarantine(rogue_id, reason="manual review")
    mgr.quarantine.readmit(rogue_id)
    assert not mgr.quarantine.machine.record_of(rogue_id).probation


def test_probes_disabled_by_default():
    mgr, clients = make_manager(
        3, quarantine_policy=ThresholdPolicy(quarantine_after=4))
    good, rogue_id = clients[0], _quarantine_rogue(mgr, clients)
    p = good.malloc(4)
    good.memcpy_h2d(p, np.zeros(4, np.float32))
    for _ in range(10):
        good.launch_kernel("bump", ptrs=[p], args=(4,))
        mgr.run_queued()
    # no readmit_after: QUARANTINED is stable until the operator acts
    assert mgr.quarantine.state_of(rogue_id) is TenantState.QUARANTINED
