"""SLO-aware tenant classes (DESIGN.md §Performance isolation): class-
resolved hold budgets (an LC op is never held past its SLO budget),
best-effort preemption at drain-cycle boundaries, compute-aware elastic
admission, per-class quarantine thresholds — and the regression contract
that a class-less (or all-best-effort-default) manager behaves
bit-identically to the pre-class scheduler.

Deterministic sweeps mirror the scheduler's hold arithmetic exactly
(queue ages are host-side cycle counts, not wall-clock); the hypothesis
mirror re-derives max-held-age = min(lookahead, budget) over random
knob settings (tests/_hyp.py convention)."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (
    AdmissionStatus,
    ElasticPolicy,
    FencePolicy,
    GuardianManager,
    TenantClass,
    TenantClassPolicy,
    TenantState,
    ThresholdPolicy,
    WeightedRatePolicy,
    as_class_policy,
)
from repro.core.quarantine import TenantRecord


def bump(arena, ptr, n):
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def bump2(arena, ptr, n):
    # fusion-incompatible twin (different kernel name/signature): the
    # best-effort flood must not join the LC tenant's batches, so the
    # LC batch stays under-filled and the lookahead hold engages
    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals + 1.0), None


def evil_write(arena, target, n):
    idx = target + jnp.arange(n, dtype=jnp.int32)
    return arena.at[idx].set(999.0), None


def _lc_be_workload(lookahead, lc_class=None, be_class=None,
                    n_ops=8, be_weight=4, **mgr_kw):
    """One LC-shaped tenant (1 op/cycle, own kernel) + one flooding
    tenant (``be_weight`` ops/cycle, incompatible kernel).  With
    ``be_weight >= lookahead`` the flooder's hold budget is 0
    (weight >= lookahead cutoff), so every nonzero queue age in
    ``stats.queue_ages`` belongs to the LC tenant — max-age assertions
    need no per-tenant attribution."""
    mgr = GuardianManager(total_slots=512, lookahead_cycles=lookahead,
                          max_fuse=16, **mgr_kw)
    lc = mgr.register_tenant("lc", 64, tenant_class=lc_class)
    be = mgr.register_tenant("be", 64, weight=be_weight,
                             tenant_class=be_class)
    lc.module_load("bump", bump)
    be.module_load("bump2", bump2)
    lp, bp = lc.malloc(8), be.malloc(8)
    for _ in range(n_ops):
        lc.launch_kernel("bump", ptrs=[lp], args=(8,))
    for _ in range(be_weight * n_ops):
        be.launch_kernel("bump2", ptrs=[bp], args=(8,))
    mgr.run_queued()
    return mgr


# ---------------------------------------------------------------------------
# Regression contract: class-less behavior is bit-identical
# ---------------------------------------------------------------------------


def test_classless_and_all_best_effort_defaults_bit_identical():
    """A best_effort() default policy inherits the global lookahead and
    never triggers preemption without a latency-critical co-tenant —
    registering every tenant as best_effort must reproduce the
    class-less run decision-for-decision (dispatch log), age-for-age,
    byte-for-byte."""
    runs = []
    for classed in (False, True):
        be = TenantClassPolicy.best_effort() if classed else None
        mgr = _lc_be_workload(lookahead=3, lc_class=be, be_class=be,
                              be_weight=2)
        runs.append((list(mgr.scheduler.dispatch_log),
                     list(mgr.scheduler.stats.queue_ages),
                     np.asarray(mgr.arena.buf)))
    assert runs[0][0] == runs[1][0], "dispatch order diverged"
    assert runs[0][1] == runs[1][1], "queue ages diverged"
    np.testing.assert_array_equal(runs[0][2], runs[1][2])


def test_classless_manager_leaves_class_machinery_cold():
    """No class policy registered: no arrival tracking, no queue-age
    EWMAs, no per-class histograms, no preemptions — the class layer
    must cost a class-less deployment nothing (and report as absent)."""
    mgr = _lc_be_workload(lookahead=2)
    sch = mgr.scheduler
    assert not mgr.has_class_tenants
    assert sch._arrival_ewma == {} and sch._qage_ewma == {}
    assert sch.stats.be_preemptions == 0
    assert sch.stats.class_queue_age == {}
    rep = mgr.metrics_report()
    assert rep["scheduler"]["queue_age_by_class"] == {}
    assert all(row["class"] is None for row in rep["tenants"].values())


# ---------------------------------------------------------------------------
# Class-resolved hold budgets: LC ops never held past their SLO budget
# ---------------------------------------------------------------------------


def test_lc_hold_budget_sweep():
    """Deterministic sweep over the global lookahead: class-less, the LC
    tenant's max queue age equals the lookahead; classed latency-critical
    with class lookahead 0 it drops to 0; inheriting the global lookahead
    (lookahead_cycles=None) it is capped at min(lookahead, budget).
    Arena bytes are identical in all runs — classes change dispatch
    timing, never results."""
    for look in (1, 2, 3, 4):
        arenas = []
        classless = _lc_be_workload(look)
        assert max(classless.scheduler.stats.queue_ages) == look
        arenas.append(np.asarray(classless.arena.buf))

        immediate = _lc_be_workload(
            look, lc_class=TenantClassPolicy.latency_critical(
                queue_age_budget=2, lookahead_cycles=0),
            be_class=TenantClassPolicy.best_effort())
        assert max(immediate.scheduler.stats.queue_ages) == 0
        by_cls = immediate.scheduler.stats.queue_age_percentiles_by_class()
        assert by_cls["latency_critical"]["p99"] == 0
        assert by_cls["latency_critical"]["count"] == 8
        arenas.append(np.asarray(immediate.arena.buf))

        budget = 2
        capped = _lc_be_workload(
            look, lc_class=TenantClassPolicy.latency_critical(
                queue_age_budget=budget, lookahead_cycles=None))
        assert max(capped.scheduler.stats.queue_ages) == min(look, budget)
        arenas.append(np.asarray(capped.arena.buf))

        for a in arenas[1:]:
            np.testing.assert_array_equal(arenas[0], a)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=10, deadline=None)
@given(look=st.integers(min_value=1, max_value=4),
       budget=st.integers(min_value=0, max_value=4))
def test_lc_hold_budget_property(look, budget):
    """Property mirror of the sweep: an inherited-lookahead LC tenant's
    max queue age is exactly min(global lookahead, SLO budget)."""
    mgr = _lc_be_workload(
        look, lc_class=TenantClassPolicy.latency_critical(
            queue_age_budget=budget, lookahead_cycles=None),
        n_ops=6)
    assert max(mgr.scheduler.stats.queue_ages) == min(look, budget)


# ---------------------------------------------------------------------------
# Best-effort preemption at drain-cycle boundaries
# ---------------------------------------------------------------------------


def test_budget_breach_defers_best_effort_batches():
    """LC with class lookahead == budget reaches its budget every hold
    period; an unsmoothed EWMA (alpha=1.0) registers the breach, and
    queued all-best-effort batches defer at the next cycle boundaries.
    Every deferred op still lands (the drain flush ignores preemption:
    result handles must fill), so arena bytes match the classless run."""
    classless = _lc_be_workload(4, be_weight=2, n_ops=12)
    preempt = _lc_be_workload(
        4, lc_class=TenantClassPolicy.latency_critical(
            queue_age_budget=2, lookahead_cycles=2, ewma_alpha=1.0),
        be_class=TenantClassPolicy.best_effort(),
        be_weight=2, n_ops=12)
    st_ = preempt.scheduler.stats
    assert st_.be_preemptions > 0
    by_cls = st_.queue_age_percentiles_by_class()
    assert by_cls["latency_critical"]["p99"] <= 2
    np.testing.assert_array_equal(np.asarray(classless.arena.buf),
                                  np.asarray(preempt.arena.buf))
    # the flight recorder saw the deferrals too
    rep = preempt.metrics_report()
    assert rep["scheduler"]["be_preemptions"] == st_.be_preemptions
    assert rep["counters"]["be_preemptions"][""] == st_.be_preemptions


def test_no_preemption_without_breach():
    """A latency-critical tenant whose ops always dispatch in their
    submission cycle (class lookahead 0) never breaches, so best-effort
    traffic is never deferred."""
    mgr = _lc_be_workload(
        4, lc_class=TenantClassPolicy.latency_critical(
            queue_age_budget=2, lookahead_cycles=0),
        be_class=TenantClassPolicy.best_effort(), be_weight=2)
    assert mgr.scheduler.stats.be_preemptions == 0


# ---------------------------------------------------------------------------
# Compute-aware elastic admission
# ---------------------------------------------------------------------------


def test_compute_aware_admission_defers_then_admits():
    """With ``compute_watermark`` set, a best-effort admission waitlists
    while the scheduler's total arrival-rate EWMA says an LC tenant is
    under compute pressure — and admits itself once the EWMA decays."""
    mgr = GuardianManager(
        total_slots=512,
        elastic_policy=ElasticPolicy(compute_watermark=1.5))
    lc = mgr.register_tenant("lc", 64, weight=2,
                             tenant_class="latency_critical")
    lc.module_load("bump", bump)
    p = lc.malloc(8)
    for _ in range(16):        # 2 ops/cycle over 8 cycles: EWMA -> 2.0
        lc.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.run_queued()
    assert mgr.scheduler.arrival_rate_total() == pytest.approx(2.0)

    adm = mgr.elastic.admit("be", 64, tenant_class="best_effort")
    assert adm.status is AdmissionStatus.WAITLISTED
    assert mgr.elastic.stats["compute_deferred"] >= 1
    # a class-less admission is never compute-deferred (pre-class
    # behavior: only memory holds it back)
    plain = mgr.elastic.admit("plain", 64)
    assert plain.status is AdmissionStatus.ADMITTED

    # light traffic decays the EWMA: 2.0 -> 1.5 (still deferred at the
    # >= watermark) -> 1.25 (admitted by the poll in run_queued)
    lc.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.run_queued()
    assert adm.status is AdmissionStatus.WAITLISTED
    lc.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.run_queued()
    assert adm.status is AdmissionStatus.ADMITTED
    assert adm.client is not None


def test_no_compute_deferral_without_watermark():
    """compute_watermark=None (the default): best-effort admissions see
    the arena-bytes-only admission path regardless of traffic."""
    mgr = GuardianManager(total_slots=512)
    lc = mgr.register_tenant("lc", 64, weight=2,
                             tenant_class="latency_critical")
    lc.module_load("bump", bump)
    p = lc.malloc(8)
    for _ in range(16):
        lc.launch_kernel("bump", ptrs=[p], args=(8,))
    mgr.run_queued()
    adm = mgr.elastic.admit("be", 64, tenant_class="best_effort")
    assert adm.status is AdmissionStatus.ADMITTED
    assert mgr.elastic.stats["compute_deferred"] == 0


# ---------------------------------------------------------------------------
# Per-class quarantine thresholds (containment folded into the policy)
# ---------------------------------------------------------------------------


def test_weighted_rate_policy_unit():
    rec = TenantRecord(tenant_id="t")
    pol = WeightedRatePolicy(quarantine_after=8,
                             weights={"scatter_oob": 4.0})
    assert pol.weighted_total({"scatter_oob": 2}) == 8.0
    assert pol.should_quarantine("t", {"scatter_oob": 2}, rec)
    assert not pol.should_quarantine("t", {"gather_oob": 7}, rec)

    rate = WeightedRatePolicy(quarantine_after=None, quarantine_rate=1.0,
                              min_cycles=4)
    rec.cycles_observed = 2      # clamped up to min_cycles=4
    assert rate.should_quarantine("t", {"gather_oob": 4}, rec)
    rec.cycles_observed = 8      # 4 / 8 = 0.5 < 1.0
    assert not rate.should_quarantine("t", {"gather_oob": 4}, rec)

    ev = WeightedRatePolicy(quarantine_after=2, evict_after=16)
    assert ev.should_quarantine("t", {"gather_oob": 2}, rec)
    assert not ev.should_evict("t", {"gather_oob": 8}, rec)
    assert ev.should_evict("t", {"gather_oob": 16}, rec)


def test_class_quarantine_threshold_overrides_global():
    """A tenant class carrying containment knobs replaces the manager's
    global policy for that tenant only: the classed offender quarantines
    at its tighter threshold while an identical class-less offender
    stays ACTIVE under the (loose) global policy."""
    mgr = GuardianManager(
        total_slots=512, policy=FencePolicy.CHECK,
        quarantine_policy=ThresholdPolicy(quarantine_after=100))
    victim = mgr.register_tenant("victim", 64)
    strict = mgr.register_tenant(
        "strict", 64,
        tenant_class=TenantClassPolicy.best_effort(quarantine_after=2))
    loose = mgr.register_tenant("loose", 64)
    vpart = mgr.bounds.lookup("victim")
    for c in (strict, loose):
        c.module_load("evil", evil_write)
        c.launch_kernel("evil", args=(jnp.int32(vpart.base), 8))
    mgr.run_queued()
    assert mgr.quarantine.state_of("strict") is TenantState.QUARANTINED
    assert mgr.quarantine.state_of("loose") is TenantState.ACTIVE


# ---------------------------------------------------------------------------
# register_tenant spec normalization + lifecycle
# ---------------------------------------------------------------------------


def test_register_tenant_class_specs():
    mgr = GuardianManager(total_slots=512)
    mgr.register_tenant("s", 32, tenant_class="latency_critical")
    mgr.register_tenant("e", 32, tenant_class=TenantClass.BEST_EFFORT)
    pol = TenantClassPolicy.latency_critical(queue_age_budget=5)
    mgr.register_tenant("p", 32, tenant_class=pol)
    mgr.register_tenant("none", 32)

    cp = mgr.class_policy_of("s")
    assert cp.is_latency_critical and cp.queue_age_budget == 2 \
        and cp.lookahead_cycles == 0       # factory defaults
    assert mgr.class_policy_of("e").is_best_effort
    assert mgr.class_policy_of("p") is pol
    assert mgr.class_policy_of("none") is None
    assert mgr.has_class_tenants

    with pytest.raises(ValueError):
        mgr.register_tenant("bad", 32, tenant_class="gold_tier")
    with pytest.raises(ValueError):
        TenantClassPolicy.latency_critical(queue_age_budget=-1)
    assert as_class_policy(None) is None

    rep = mgr.metrics_report()
    assert rep["tenants"]["s"]["class"] == "latency_critical"
    assert rep["tenants"]["none"]["class"] is None

    # teardown clears the class registry (has_class_tenants is the
    # scheduler's master switch — it must not stick after departures)
    for t in ("s", "e", "p"):
        mgr.remove_tenant(t)
    assert not mgr.has_class_tenants


# ---------------------------------------------------------------------------
# Serving plane: LC generations undisturbed by a best-effort flood
# ---------------------------------------------------------------------------


def test_serve_lc_generations_identical_under_be_flood():
    """ISSUE 8 acceptance: a latency-critical serve tenant's generations
    are byte-identical to its solo run while best-effort co-tenants
    flood the engine."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("stablelm-3b").reduced()
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    floods = [rng.integers(0, cfg.vocab, size=10).astype(np.int32)
              for _ in range(4)]

    solo = ServeEngine(cfg, max_batch=8, max_len=64)
    solo.register_tenant("lc", 2, tenant_class="latency_critical")
    rid = solo.submit("lc", prompt)
    want = solo.run(max_new_tokens=6)[rid]

    eng = ServeEngine(cfg, max_batch=8, max_len=64)
    eng.register_tenant("lc", 2, tenant_class="latency_critical")
    eng.register_tenant("be0", 2, tenant_class="best_effort")
    eng.register_tenant("be1", 2, tenant_class="best_effort")
    rid2 = eng.submit("lc", prompt)
    for i, fp in enumerate(floods):
        eng.submit(f"be{i % 2}", fp)
    out = eng.run(max_new_tokens=6)
    assert out[rid2] == want, "best-effort flood perturbed LC generations"
    rep = eng.manager.metrics_report()
    assert rep["tenants"]["lc"]["class"] == "latency_critical"
    assert rep["tenants"]["be0"]["class"] == "best_effort"
