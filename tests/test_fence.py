"""Property tests for the three bounds modes (Guardian §4.4).

Hypothesis-based property tests skip cleanly when hypothesis is absent
(optional dev dependency); each property has a deterministic seeded-sweep
mirror below that always runs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.fence import (
    FenceParams,
    FencePolicy,
    FenceTable,
    apply_fence,
    apply_fence_mixed,
    fence_bitwise,
    fence_check,
    fence_modulo,
    fence_modulo_magic,
    fence_modulo_magic_dyn,
    magic_constants,
    magic_row,
    require_pow2_sizes,
)
from repro.core.partition import Partition

POW2_SIZES = [1, 2, 4, 8, 64, 1024, 1 << 20]
pow2_sizes = st.sampled_from(POW2_SIZES)


@given(pow2_sizes, st.integers(min_value=0, max_value=63),
       st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
                min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_bitwise_containment_and_identity(size, base_mult, idxs):
    base = base_mult * size            # size-aligned (invariant I2)
    if base + size > 2**31 - 1:
        return
    out = np.asarray(fence_bitwise(jnp.asarray(idxs, jnp.int32),
                                   base, size - 1))
    assert ((out >= base) & (out < base + size)).all()
    inside = [i for i in idxs if base <= i < base + size]
    out_in = np.asarray(fence_bitwise(jnp.asarray(inside, jnp.int32),
                                      base, size - 1)) if inside else []
    assert list(out_in) == inside


@given(st.integers(min_value=1, max_value=2**20))
@settings(max_examples=200, deadline=None)
def test_magic_constants_division(d):
    m, s = magic_constants(d)
    for n in [0, 1, d - 1, d, d + 1, 12345, 2**30, 2**31 - 1]:
        assert (n * m) >> s == n // d, (n, d)


@given(st.integers(min_value=1, max_value=4096),
       st.integers(min_value=0, max_value=1000),
       st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_modulo_magic_matches_plain(size, base, idxs):
    idx = jnp.asarray(idxs, jnp.int32)
    m, s = magic_constants(size)
    a = np.asarray(fence_modulo(idx, base, size))
    b = np.asarray(fence_modulo_magic(idx, base, size, m, s))
    np.testing.assert_array_equal(a, b)
    assert ((b >= base) & (b < base + size)).all()


def test_check_detects():
    idx = jnp.asarray([5, 10, 15, 16, -1], jnp.int32)
    safe, ok = fence_check(idx, base=5, size=11)
    np.testing.assert_array_equal(np.asarray(ok),
                                  [True, True, True, False, False])
    assert (np.asarray(safe)[~np.asarray(ok)] == 5).all()


def test_apply_fence_dispatch():
    idx = jnp.asarray([100], jnp.int32)
    p = FenceParams(base=0, size=64)
    out, ok = apply_fence(FencePolicy.NONE, idx, p)
    assert int(out[0]) == 100 and ok is None
    out, ok = apply_fence(FencePolicy.BITWISE, idx, p)
    assert int(out[0]) == 100 & 63 and ok is None
    out, ok = apply_fence(FencePolicy.MODULO, idx, p)
    assert int(out[0]) == 100 % 64
    out, ok = apply_fence(FencePolicy.CHECK, idx, p)
    assert not bool(ok[0]) and int(out[0]) == 0


def test_fence_params_traced_vs_static():
    p = FenceParams(base=jnp.int32(64), size=jnp.int32(64))
    assert not p.is_static
    with pytest.raises(ValueError):
        _ = p.magic   # modulo needs concrete size
    q = FenceParams(base=64, size=64)
    assert q.is_static and q.mask == 63


@given(st.integers(min_value=0, max_value=3),
       st.lists(st.integers(min_value=-100, max_value=100), min_size=4,
                max_size=4))
@settings(max_examples=50, deadline=None)
def test_per_row_fencing(row, idxs):
    """Batched serving: per-row (base, mask) arrays fence elementwise."""
    base = jnp.asarray([0, 16, 32, 48], jnp.int32)
    mask = jnp.asarray([15, 15, 15, 15], jnp.int32)
    idx = jnp.asarray(idxs, jnp.int32)
    out = np.asarray(fence_bitwise(idx, base, mask))
    for r in range(4):
        assert 16 * r <= out[r] < 16 * (r + 1)


# ---------------------------------------------------------------------------
# Deterministic seeded-sweep mirrors of the hypothesis properties above.
# These always run (the property tests skip when hypothesis is absent).
# ---------------------------------------------------------------------------


def test_bitwise_containment_and_identity_sweep():
    rng = np.random.default_rng(0)
    for size in POW2_SIZES:
        for base_mult in (0, 1, 7, 63):
            base = base_mult * size             # size-aligned (invariant I2)
            if base + size > 2**31 - 1:
                continue
            idxs = rng.integers(-(2**31), 2**31 - 1, size=64,
                                dtype=np.int64).astype(np.int32)
            out = np.asarray(fence_bitwise(jnp.asarray(idxs), base,
                                           size - 1))
            assert ((out >= base) & (out < base + size)).all()
            inside = base + rng.integers(0, size, size=16).astype(np.int32)
            out_in = np.asarray(fence_bitwise(jnp.asarray(inside), base,
                                              size - 1))
            np.testing.assert_array_equal(out_in, inside)


def test_magic_constants_division_sweep():
    rng = np.random.default_rng(1)
    divisors = sorted({1, 2, 3, 5, 7, 64, 100, 255, 256, 1 << 19,
                       (1 << 20) - 1, 1 << 20,
                       *rng.integers(1, 1 << 20, size=200).tolist()})
    for d in divisors:
        m, s = magic_constants(d)
        for n in [0, 1, d - 1, d, d + 1, 12345, 2**30, 2**31 - 1]:
            assert (n * m) >> s == n // d, (n, d)


def test_modulo_magic_matches_plain_sweep():
    """Bit-identity of the reciprocal form vs the plain remainder form."""
    rng = np.random.default_rng(2)
    sizes = sorted({1, 2, 3, 5, 17, 64, 100, 1000, 4096,
                    *rng.integers(1, 4096, size=40).tolist()})
    for size in sizes:
        base = int(rng.integers(0, 1000))
        idx = jnp.asarray(rng.integers(0, 2**31 - 1, size=32,
                                       dtype=np.int64).astype(np.int32))
        m, s = magic_constants(size)
        a = np.asarray(fence_modulo(idx, base, size))
        b = np.asarray(fence_modulo_magic(idx, base, size, m, s))
        np.testing.assert_array_equal(a, b)
        assert ((b >= base) & (b < base + size)).all()


def test_per_row_fencing_sweep():
    rng = np.random.default_rng(3)
    base = jnp.asarray([0, 16, 32, 48], jnp.int32)
    mask = jnp.asarray([15, 15, 15, 15], jnp.int32)
    for _ in range(25):
        idx = jnp.asarray(rng.integers(-100, 100, size=4).astype(np.int32))
        out = np.asarray(fence_bitwise(idx, base, mask))
        for r in range(4):
            assert 16 * r <= out[r] < 16 * (r + 1)


# ---------------------------------------------------------------------------
# Traced-params contract + FenceTable (batched rows)
# ---------------------------------------------------------------------------


def test_traced_mask_contract_requires_host_validation():
    """A *traced* non-pow2 size cannot be rejected at trace time — mask
    silently computes size-1 (wrap guarantee broken).  The contract is that
    callers validate host-known sizes with require_pow2_sizes first."""
    # static non-pow2: rejected eagerly
    with pytest.raises(ValueError):
        _ = FenceParams(base=0, size=48).mask
    # traced non-pow2: NOT rejected (documented limitation)...
    p = FenceParams(base=jnp.int32(0), size=jnp.int32(48))
    assert int(p.mask) == 47
    # ...so the host-side validator is the enforcement point:
    with pytest.raises(ValueError):
        require_pow2_sizes(48)
    with pytest.raises(ValueError):
        require_pow2_sizes([64, 48, 16])
    with pytest.raises(ValueError):
        require_pow2_sizes(0)
    require_pow2_sizes([1, 2, 64, 1 << 20])   # all pow2: fine
    # non-integer / traced inputs are a programming error
    with pytest.raises(ValueError):
        require_pow2_sizes(np.asarray([64.0]))


def test_fence_table_rows_and_gather():
    parts = [Partition("a", base=0, size=16),
             Partition("b", base=16, size=16),
             Partition("c", base=64, size=64)]
    tbl = FenceTable.from_partitions(parts)
    assert len(tbl) == 3
    assert tbl.rows.shape == (3, 2)
    np.testing.assert_array_equal(np.asarray(tbl.rows),
                                  [[0, 15], [16, 15], [64, 63]])
    # row_params: traced per-row FenceParams
    rp = tbl.row_params(2)
    fenced = np.asarray(fence_bitwise(jnp.asarray([999], jnp.int32),
                                      rp.base, rp.mask))
    assert 64 <= fenced[0] < 128
    # gather by tenant-id column: elementwise fencing per owner
    col = jnp.asarray([0, 1, 2, 1], jnp.int32)
    params = tbl.gather(col)
    idx = jnp.asarray([9999, -3, 70, 17], jnp.int32)
    out = np.asarray(fence_bitwise(idx, params.base, params.mask))
    assert 0 <= out[0] < 16          # wrapped into a
    assert 16 <= out[1] < 32         # wrapped into b
    assert out[2] == 70              # identity inside c
    assert out[3] == 17              # identity inside b


def test_fence_table_validates_pow2():
    with pytest.raises(ValueError):
        FenceTable.from_bounds(base=[0, 16], size=[16, 48])
    tbl = FenceTable.from_bounds(base=[0, 16], size=[16, 16])
    np.testing.assert_array_equal(np.asarray(tbl.rows),
                                  [[0, 15], [16, 15]])
    with pytest.raises(ValueError):
        FenceTable.from_partitions([])


# ---------------------------------------------------------------------------
# Dynamic magic constants (fused MODULO) + row-mixed policy dispatch
# ---------------------------------------------------------------------------


def _dyn_magic_args(size):
    m, s = magic_row(size)
    return (jnp.asarray(np.uint32(m).view(np.int32)), jnp.int32(s))


@given(st.integers(min_value=1, max_value=2**20),
       st.integers(min_value=0, max_value=1000),
       st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
                min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_modulo_magic_dyn_matches_static(size, base, idxs):
    """The traced-constant reciprocal modulo is bit-identical to the
    static per-partition specialization — the equivalence MODULO fusion
    rests on.  Covers non-pow2 sizes and the size-1 degenerate row."""
    idx = jnp.asarray(idxs, jnp.int32)
    if size > 1:
        m, s = magic_constants(size)
        ref = np.asarray(fence_modulo_magic(idx, base, size, m, s))
    else:
        ref = np.full(idx.shape, base, np.int32)
    mm, ms = _dyn_magic_args(size)
    dyn = np.asarray(fence_modulo_magic_dyn(
        idx, jnp.int32(base), jnp.int32(size), mm, ms))
    np.testing.assert_array_equal(ref, dyn)
    assert ((dyn >= base) & (dyn < base + size)).all()


def test_modulo_magic_dyn_matches_static_sweep():
    rng = np.random.default_rng(11)
    for size in [1, 2, 3, 7, 16, 48, 100, 1000, (1 << 20) + 3]:
        base = int(rng.integers(0, 1000))
        idx = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, 64), jnp.int32)
        if size > 1:
            m, s = magic_constants(size)
            ref = np.asarray(fence_modulo_magic(idx, base, size, m, s))
        else:
            ref = np.full(idx.shape, base, np.int32)
        mm, ms = _dyn_magic_args(size)
        dyn = np.asarray(fence_modulo_magic_dyn(
            idx, jnp.int32(base), jnp.int32(size), mm, ms))
        np.testing.assert_array_equal(ref, dyn, err_msg=f"size={size}")


def test_apply_fence_modulo_uses_dyn_when_magic_params_present():
    """Magic-carrying FenceParams (gathered from a table) switch the
    MODULO dispatch to the traced reciprocal — no concrete-size error."""
    idx = jnp.asarray([100, -5, 63], jnp.int32)
    mm, ms = _dyn_magic_args(48)
    p = FenceParams(base=jnp.int32(0), size=jnp.int32(48),
                    magic_m=mm, magic_s=ms)
    out, ok = apply_fence(FencePolicy.MODULO, idx, p)
    assert ok is None
    np.testing.assert_array_equal(
        np.asarray(out), [100 % 48, (-5 & 0x7FFFFFFF) % 48, 63 % 48])
    # traced size without magic still fails loudly (structural shift)
    with pytest.raises(ValueError):
        apply_fence(FencePolicy.MODULO, idx,
                    FenceParams(base=jnp.int32(0), size=jnp.int32(48)))


def test_fence_table_magic_rows_and_mixed_gather():
    """from_partitions(with_magic=True) carries a (T, 4) magic table;
    modulo_from_bounds accepts non-pow2 sizes; gather returns params that
    drive apply_fence_mixed per element."""
    parts = [Partition("a", base=0, size=16),
             Partition("b", base=16, size=16)]
    tbl = FenceTable.from_partitions(parts, with_magic=True)
    assert tbl.magic.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(tbl.magic)[:, :2],
                                  [[0, 16], [16, 16]])

    npw = FenceTable.modulo_from_bounds([0, 48, 60], [48, 12, 1])
    assert npw.rows is None and len(npw) == 3
    params = npw.gather(jnp.asarray([0, 1, 2, 1], jnp.int32))
    idx = jnp.asarray([100, 49, 999, 45], jnp.int32)
    codes = jnp.asarray([FencePolicy.MODULO.code, FencePolicy.MODULO.code,
                         FencePolicy.CHECK.code, FencePolicy.CHECK.code],
                        jnp.int32)
    fenced, ok = apply_fence_mixed(codes, idx, params)
    fenced, ok = np.asarray(fenced), np.asarray(ok)
    assert fenced[0] == 100 % 48
    assert fenced[1] == 48 + (49 - 48) % 12
    assert fenced[2] == 60 and not ok[2]      # CHECK: clamped + detected
    assert fenced[3] == 48 and not ok[3]      # below base -> clamped too
    # mixed dispatch without magic params fails loudly
    with pytest.raises(ValueError):
        apply_fence_mixed(codes, idx, FenceParams(base=0, size=16))


def test_magic_row_degenerate_divisor():
    assert magic_row(1) == (0, 32)
    assert magic_row(2) == magic_constants(2)
    with pytest.raises(ValueError):
        magic_constants(0)
