"""Property tests for the three bounds modes (Guardian §4.4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fence import (
    FenceParams,
    FencePolicy,
    apply_fence,
    fence_bitwise,
    fence_check,
    fence_modulo,
    fence_modulo_magic,
    magic_constants,
)

pow2_sizes = st.sampled_from([1, 2, 4, 8, 64, 1024, 1 << 20])


@given(pow2_sizes, st.integers(min_value=0, max_value=63),
       st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
                min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_bitwise_containment_and_identity(size, base_mult, idxs):
    base = base_mult * size            # size-aligned (invariant I2)
    if base + size > 2**31 - 1:
        return
    out = np.asarray(fence_bitwise(jnp.asarray(idxs, jnp.int32),
                                   base, size - 1))
    assert ((out >= base) & (out < base + size)).all()
    inside = [i for i in idxs if base <= i < base + size]
    out_in = np.asarray(fence_bitwise(jnp.asarray(inside, jnp.int32),
                                      base, size - 1)) if inside else []
    assert list(out_in) == inside


@given(st.integers(min_value=1, max_value=2**20))
@settings(max_examples=200, deadline=None)
def test_magic_constants_division(d):
    m, s = magic_constants(d)
    for n in [0, 1, d - 1, d, d + 1, 12345, 2**30, 2**31 - 1]:
        assert (n * m) >> s == n // d, (n, d)


@given(st.integers(min_value=1, max_value=4096),
       st.integers(min_value=0, max_value=1000),
       st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_modulo_magic_matches_plain(size, base, idxs):
    idx = jnp.asarray(idxs, jnp.int32)
    m, s = magic_constants(size)
    a = np.asarray(fence_modulo(idx, base, size))
    b = np.asarray(fence_modulo_magic(idx, base, size, m, s))
    np.testing.assert_array_equal(a, b)
    assert ((b >= base) & (b < base + size)).all()


def test_check_detects():
    idx = jnp.asarray([5, 10, 15, 16, -1], jnp.int32)
    safe, ok = fence_check(idx, base=5, size=11)
    np.testing.assert_array_equal(np.asarray(ok),
                                  [True, True, True, False, False])
    assert (np.asarray(safe)[~np.asarray(ok)] == 5).all()


def test_apply_fence_dispatch():
    idx = jnp.asarray([100], jnp.int32)
    p = FenceParams(base=0, size=64)
    out, ok = apply_fence(FencePolicy.NONE, idx, p)
    assert int(out[0]) == 100 and ok is None
    out, ok = apply_fence(FencePolicy.BITWISE, idx, p)
    assert int(out[0]) == 100 & 63 and ok is None
    out, ok = apply_fence(FencePolicy.MODULO, idx, p)
    assert int(out[0]) == 100 % 64
    out, ok = apply_fence(FencePolicy.CHECK, idx, p)
    assert not bool(ok[0]) and int(out[0]) == 0


def test_fence_params_traced_vs_static():
    p = FenceParams(base=jnp.int32(64), size=jnp.int32(64))
    assert not p.is_static
    with pytest.raises(ValueError):
        _ = p.magic   # modulo needs concrete size
    q = FenceParams(base=64, size=64)
    assert q.is_static and q.mask == 63


@given(st.integers(min_value=0, max_value=3),
       st.lists(st.integers(min_value=-100, max_value=100), min_size=4,
                max_size=4))
@settings(max_examples=50, deadline=None)
def test_per_row_fencing(row, idxs):
    """Batched serving: per-row (base, mask) arrays fence elementwise."""
    base = jnp.asarray([0, 16, 32, 48], jnp.int32)
    mask = jnp.asarray([15, 15, 15, 15], jnp.int32)
    idx = jnp.asarray(idxs, jnp.int32)
    out = np.asarray(fence_bitwise(idx, base, mask))
    for r in range(4):
        assert 16 * r <= out[r] < 16 * (r + 1)
