"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body
**once**, which silently undercounts scan-over-layers programs by ~n_layers
x (and the collectives inside FSDP bodies with them).  This module parses
the post-SPMD, per-device HLO text into computations, recovers each while
loop's trip count from the ``constant(N)`` in its condition computation,
and aggregates:

    flops               dot/convolution FLOPs (MXU)            x trip counts
    vector_flops        elementwise estimate (1 flop/elem of fusion outputs)
    bytes               fusion-level HBM traffic model: every top-level
                        op's operand+result bytes (fusion internals free)
    collective_bytes    per-kind operand bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute

The model is deterministic and structural — exactly what a dry-run roofline
needs (no wall clock, no hardware).  Perf iterations diff these numbers.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")

_SKIP_OPS = {"tuple", "get-tuple-element", "parameter", "after-all",
             "bitcast", "partition-id", "replica-id", "iota", "constant",
             "add-dependency", "domain", "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    op: str
    line: str

    @property
    def operands(self) -> List[str]:
        call = self.line[self.line.index(self.op + "(") + len(self.op):]
        depth, buf = 0, ""
        for ch in call:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                buf += ch
        return re.findall(r"%([\w.\-]+)", buf)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=([^,]+(?:\{[^}]*\})?)", self.line)
        return m.group(1) if m else None


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    vector_flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())

    def scaled(self, f: float) -> "Costs":
        return Costs(
            flops=self.flops * f, vector_flops=self.vector_flops * f,
            bytes=self.bytes * f,
            collectives={k: v * f for k, v in self.collectives.items()},
            collective_counts={k: int(v * f) for k, v in
                               self.collective_counts.items()})

    def add(self, o: "Costs") -> None:
        self.flops += o.flops
        self.vector_flops += o.vector_flops
        self.bytes += o.bytes
        for k in self.collectives:
            self.collectives[k] += o.collectives[k]
            self.collective_counts[k] += o.collective_counts[k]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.sizes: Dict[str, int] = {}
        self.types: Dict[str, str] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Costs] = {}
        self.entry = self._entry_name(hlo_text)

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s:
                continue
            if (not line.startswith(" ")) and ("{" in s):
                m = _HEADER_RE.match(s)
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                    continue
            if s == "}":
                continue
            m = _DEF_RE.match(line)
            if m and current is not None:
                op = _Op(name=m.group(1), type_str=m.group(2),
                         op=m.group(3), line=line)
                self.computations[current].append(op)
                self.sizes[op.name] = _type_bytes(op.type_str)
                self.types[op.name] = op.type_str

    def _entry_name(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _HEADER_RE.match(line.strip())
                if m:
                    return m.group(1)
        # fallback: last computation
        return list(self.computations)[-1] if self.computations else ""

    # ------------------------------------------------------------------ #
    def trip_count(self, cond_name: str) -> int:
        """Largest s32 constant in the while condition computation."""
        best = 1
        for op in self.computations.get(cond_name, []):
            if op.op == "constant" and "s32[]" in op.type_str:
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    best = max(best, int(m.group(1)))
        # the cond may delegate the compare to a fused computation whose
        # constant operand lives here — already covered (constant is here).
        return best

    def _operand_bytes(self, op: _Op) -> int:
        return sum(self.sizes.get(n, 0) for n in op.operands)

    def _dot_flops(self, op: _Op) -> float:
        """2 x result_elems x contracted_elems."""
        result = _type_elems(op.type_str)
        lhs = op.operands[0] if op.operands else None
        lhs_shape = None
        if lhs in self.types:
            sd = _shape_dims(self.types[lhs])
            if sd:
                lhs_shape = sd[0][1]
        contract = 1
        cdims = op.attr("lhs_contracting_dims")
        if lhs_shape is not None and cdims:
            for d in re.findall(r"\d+", cdims):
                di = int(d)
                if di < len(lhs_shape):
                    contract *= lhs_shape[di]
        return 2.0 * result * contract

    # ------------------------------------------------------------------ #
    def analyze(self, comp: Optional[str] = None) -> Costs:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        self._memo[comp] = total   # cycle guard
        for op in self.computations.get(comp, []):
            kind = next((c for c in _COLLECTIVES
                         if op.op == c or op.op == c + "-start"), None)
            if op.op in _SKIP_OPS:
                continue
            if op.op.endswith("-done"):
                continue
            if kind is not None:
                b = self._operand_bytes(op) or _type_bytes(op.type_str)
                total.collectives[kind] += b
                total.collective_counts[kind] += 1
                total.bytes += b + _type_bytes(op.type_str)
                continue
            if op.op == "while":
                cond = op.attr("condition")
                body = op.attr("body")
                trip = self.trip_count(cond.lstrip("%")) if cond else 1
                if body:
                    total.add(self.analyze(body.lstrip("%")).scaled(trip))
                continue
            if op.op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.line)
                names = (re.findall(r"%([\w.\-]+)", branches[0])
                         if branches else [])
                tc = op.attr("true_computation")
                fc = op.attr("false_computation")
                names += [x.lstrip("%") for x in (tc, fc) if x]
                if names:
                    subs = [self.analyze(n) for n in names]
                    # worst case branch
                    total.add(max(subs, key=lambda c: c.flops + c.bytes))
                continue
            if op.op == "call":
                to = op.attr("to_apply")
                if to:
                    total.add(self.analyze(to.lstrip("%")))
                continue
            if op.op in ("dot", "convolution"):
                total.flops += self._dot_flops(op)
                total.bytes += self._operand_bytes(op) + \
                    _type_bytes(op.type_str)
                continue
            if op.op == "fusion":
                # fused dots live inside the called computation
                called = op.attr("calls")
                if called:
                    for o in self.computations.get(called.lstrip("%"), []):
                        if o.op in ("dot", "convolution"):
                            total.flops += self._dot_flops(o)
            # generic top-level op: HBM traffic = operands + result
            total.bytes += self._operand_bytes(op) + _type_bytes(op.type_str)
            total.vector_flops += _type_elems(op.type_str)
        self._memo[comp] = total
        return total


def analyze_hlo(hlo_text: str) -> Costs:
    return HloCostModel(hlo_text).analyze()
