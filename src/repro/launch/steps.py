"""Step builders — train / prefill / decode as pjit-ready callables with
full sharding trees.

``build_step(cfg, shape, mesh, ...)`` returns a :class:`StepBundle`:

    fn              the python callable (pure)
    in_specs        pytree of ShapeDtypeStructs (the dry-run inputs)
    in_shardings    matching NamedShardings
    out_shardings   NamedShardings (or None -> let GSPMD choose)
    donate_argnums  buffers that alias in-place (params/opt/cache)

Used by dryrun.py (lower+compile with abstract inputs), train.py and
serve.py (real execution).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.fence import FenceParams, FencePolicy
from repro.distributed.sharding import ShardingRules, make_rules
from repro.models import kvcache as KV
from repro.models.api import ModelAPI, get_model
from repro.models.encdec import EncDecCache
from repro.models.guard import GuardSpec
from repro.models.hybrid import HybridCache
from repro.optim import adamw, apply_updates, cosine


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_specs: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    rules: ShardingRules
    api: ModelAPI


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _is_axes(x):
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def _shard_tree(mesh: Mesh, rules: ShardingRules, axes_tree,
                shape_tree=None):
    """Logical axes -> NamedShardings.  When ``shape_tree`` is given,
    dimensions whose size is not divisible by the mapped mesh-axis size
    are replicated instead (input shardings, unlike constraints, require
    exact divisibility)."""
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: rules.sharding(mesh, axes), axes_tree,
            is_leaf=_is_axes)

    def one(axes, shaped):
        dims = tuple(shaped.shape)
        checked = []
        for i, logical in enumerate(axes):
            mesh_axis = rules.lookup(logical) if logical else None
            if mesh_axis is not None and i < len(dims) and \
                    dims[i] % _axis_size(mesh, mesh_axis) != 0:
                mesh_axis = None
            checked.append(mesh_axis)
        return NamedSharding(mesh, P(*checked))

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=_is_axes)


def _batch_axes(specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def make_guard(cfg: ModelConfig, shape: ShapeConfig,
               policy: FencePolicy = FencePolicy.BITWISE,
               enabled: bool = True) -> Optional[GuardSpec]:
    """Default single-tenant-owns-everything guard (fences still compiled
    in — the overhead-measurement configuration).  ``enabled=False`` is the
    paper's standalone fast path (no fence instructions emitted)."""
    if not enabled:
        return None
    import math

    def pow2(n):
        return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1

    slots = pow2(shape.global_batch)
    pages = pow2(max(shape.seq_len // KV.PAGE_SIZE, 1))
    vocab = pow2(cfg.vocab)
    expert = pow2(cfg.moe.num_experts) if cfg.moe else 0

    def fp(n):
        return FenceParams(base=0, size=n) if n else None

    return GuardSpec(policy=policy, vocab=fp(vocab), kv=fp(slots),
                     state=fp(slots), expert=fp(expert), page=fp(pages))


# ---------------------------------------------------------------------------
# Cache sharding-axes trees (structure-matched to the cache pytrees)
# ---------------------------------------------------------------------------

def _kv_pool_axes(mesh: Mesh, n_kv_heads: int):
    """(L, slots, P, page, KH, D) sharding for the KV pool.

    KH shards over the model axis when divisible; otherwise the model
    axis falls back to head_dim (all assigned archs have head_dim
    divisible by 16) so the pool is never replicated across TP ranks."""
    model = mesh.shape.get("model", 1)
    if n_kv_heads % model == 0:
        return (None, "pages", None, None, "kv_heads", None)
    return (None, "pages", None, None, None, "heads")


def _paged_axes(mesh, cache_shape: KV.PagedKVCache,
                n_kv_heads: int) -> KV.PagedKVCache:
    kv = _kv_pool_axes(mesh, n_kv_heads)
    return KV.PagedKVCache(k=kv, v=kv, page_table=("batch", None),
                           slot_ids=("batch",), seq_lens=("batch",))


def _state_axes(cache_shape: KV.StateCache) -> KV.StateCache:
    pools = {name: (None, "pages") + (None,) * (len(arr.shape) - 2)
             for name, arr in cache_shape.pools.items()}
    return KV.StateCache(pools=pools, slot_ids=("batch",),
                         seq_lens=("batch",))


def cache_axes(mesh, cfg, cache_shape):
    if isinstance(cache_shape, KV.PagedKVCache):
        return _paged_axes(mesh, cache_shape, cfg.n_kv_heads)
    if isinstance(cache_shape, KV.StateCache):
        return _state_axes(cache_shape)
    if isinstance(cache_shape, HybridCache):
        return HybridCache(
            kv=_paged_axes(mesh, cache_shape.kv, cfg.n_kv_heads),
            state=_state_axes(cache_shape.state))
    if isinstance(cache_shape, EncDecCache):
        model = mesh.shape.get("model", 1)
        if cfg.n_kv_heads % model == 0:
            cross = (None, "pages", None, "kv_heads", None)
        else:
            cross = (None, "pages", None, None, "heads")
        return EncDecCache(
            kv=_paged_axes(mesh, cache_shape.kv, cfg.n_kv_heads),
            cross_k=cross, cross_v=cross, src_lens=("batch",))
    raise TypeError(type(cache_shape))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                     fsdp: bool = True, guard_enabled: bool = True,
                     policy: FencePolicy = FencePolicy.BITWISE,
                     remat: bool = True,
                     peak_lr: float = 3e-4,
                     moe_dispatch: str = "scatter",
                     remat_policy: str = "nothing") -> StepBundle:
    api = get_model(cfg)
    rules = make_rules(mesh, fsdp=fsdp)
    guard = make_guard(cfg, shape, policy, guard_enabled)
    opt = adamw(cosine(peak_lr, 2_000, 100_000))
    extra = {"dispatch": moe_dispatch,
             "remat_policy": remat_policy} if cfg.moe else {}

    def train_step(params, opt_state, batch):
        def loss_of(p):
            return api.loss(p, batch, guard=guard, rules=rules,
                            remat=remat, **extra)
        loss, grads = jax.value_and_grad(loss_of)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    # abstract trees
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    batch_specs = api.batch_specs(shape)

    p_axes = api.param_logical_axes()
    p_shard = _shard_tree(mesh, rules, p_axes, params_shape)
    # optimizer state: m/v/vr/vc inherit param sharding; scalars replicated
    rep = NamedSharding(mesh, P())

    def opt_shardings(opt_tree):
        def walk(sub, ps):
            if isinstance(sub, dict) and ("m" in sub or "v" in sub):
                out = {}
                for k, v in sub.items():
                    if k == "step":
                        out[k] = rep
                    else:
                        out[k] = jax.tree.map(lambda a, s: s, v, ps) \
                            if _same_structure(v, ps) else jax.tree.map(
                                lambda a: rep, v)
                return out
            return jax.tree.map(lambda a: rep, sub)
        return walk(opt_tree, p_shard)

    def _same_structure(a, b):
        try:
            jax.tree.map(lambda x, y: None, a, b)
            return True
        except ValueError:
            return False

    o_shard = opt_shardings(opt_shape)
    b_shard = _shard_tree(mesh, rules, _batch_axes(batch_specs),
                          batch_specs)

    return StepBundle(
        fn=train_step,
        in_specs=(params_shape, opt_shape, batch_specs),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard,
                       {"loss": NamedSharding(mesh, P())}),
        donate_argnums=(0, 1),
        rules=rules,
        api=api,
    )


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode)
# ---------------------------------------------------------------------------

_KV_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32,
              "f8": jnp.float8_e4m3fn}


def split_cache_pool(cache) -> Tuple[Dict, Any]:
    """Split a serve cache pytree into ``(pool, meta)``.

    ``pool`` collects the slot-indexed tensors (KV page pools, SSM state
    pools, cross-attention pools — everything partitioned on the shared
    slot axis); ``meta`` is the same cache dataclass with the pool fields
    stripped (per-batch-row state only: slot ids, seq lens, page tables).
    The pool half lives as a manager-owned :class:`PoolArena` so N serve
    engines address ONE slot space; the meta half stays a per-engine step
    operand.  Inverse of :func:`join_cache_pool`.
    """
    if hasattr(cache, "kv"):          # hybrid / encdec: recurse
        kv_pool, kv_meta = split_cache_pool(cache.kv)
        pool: Dict[str, Any] = {"kv": kv_pool}
        repl: Dict[str, Any] = {"kv": kv_meta}
        if hasattr(cache, "state"):
            sp, sm = split_cache_pool(cache.state)
            pool["state"] = sp
            repl["state"] = sm
        if hasattr(cache, "cross_k"):
            pool["cross_k"] = cache.cross_k
            pool["cross_v"] = cache.cross_v
            repl["cross_k"] = None
            repl["cross_v"] = None
        return pool, dataclasses.replace(cache, **repl)
    if hasattr(cache, "pools"):
        return {"pools": cache.pools}, dataclasses.replace(cache, pools={})
    return {"k": cache.k, "v": cache.v}, \
        dataclasses.replace(cache, k=None, v=None)


def join_cache_pool(pool: Dict, meta) -> Any:
    """Rebuild the full cache pytree from a pool dict + meta cache."""
    if hasattr(meta, "kv"):
        repl: Dict[str, Any] = {"kv": join_cache_pool(pool["kv"], meta.kv)}
        if hasattr(meta, "state"):
            repl["state"] = join_cache_pool(pool["state"], meta.state)
        if hasattr(meta, "cross_k"):
            repl["cross_k"] = pool["cross_k"]
            repl["cross_v"] = pool["cross_v"]
        return dataclasses.replace(meta, **repl)
    if hasattr(meta, "pools"):
        return dataclasses.replace(meta, pools=pool["pools"])
    return dataclasses.replace(meta, k=pool["k"], v=pool["v"])


@dataclasses.dataclass(frozen=True)
class TrustedStepBundle:
    """The serving engine's prefill/decode as *trusted manager kernels*
    (see ``GuardianManager.register_trusted_kernel``): internally fenced
    via a per-row GuardSpec, params/meta/guard passed as operands (never
    closed over — closures would bake the weights into every compiled
    step), the flat manager arena AND the shared KV pool arena threaded
    through (``fn(arena, pool, params, meta, x, guard) ->
    (arena, pool, (meta, next_ids))``).

    Names carry a pool fingerprint (model shape + pool geometry) so
    engines serving the *same* model shape share one symbol entry — and
    therefore one compiled step that the scheduler can fuse across
    engines, all addressing one manager-owned pool — while engines
    serving different models stay on separate entries (a shared name with
    different step functions would silently run the first engine's model
    for everyone).
    """

    pool_name: str
    prefill_name: str
    decode_name: str
    prefill_fn: Callable
    decode_fn: Callable
    #: demand an extent-mode static bounds proof on the first dispatch of
    #: each operand signature instead of blind trust — see
    #: ``GuardianManager.register_trusted_kernel(verify=True)``
    verify: bool = False

    def register(self, manager, pool: Dict) -> Any:
        """Adopt ``pool`` as the manager arena (idempotent — co-hosted
        engines converge on the first-registered pool) and register both
        step kernels against it.  Returns the live PoolArena."""
        arena = manager.register_pool(self.pool_name, pool)
        manager.register_trusted_kernel(
            self.prefill_name, self.prefill_fn, pool_arena=self.pool_name,
            verify=self.verify)
        manager.register_trusted_kernel(
            self.decode_name, self.decode_fn, pool_arena=self.pool_name,
            verify=self.verify)
        return arena


def build_trusted_serve_steps(api: ModelAPI, pool_key: str,
                              verify: bool = False,
                              step_key: Optional[str] = None,
                              temperature: float = 0.0,
                              top_k: int = 0) -> TrustedStepBundle:
    """Trusted prefill/decode step functions for one model API.

    The step rebuilds the cache from the manager-threaded pool + the
    engine's meta operand, runs the model, and splits the result back.
    Sampling happens *inside* the step: the engine's decode loop stays
    fully asynchronous — per step it receives ``(meta, next_ids)`` and
    never materializes the ``(B, vocab)`` logits on the host.

    ``pool_key`` must identify the pool geometry (slot count, page
    layout); ``step_key`` (default: ``pool_key``) additionally carries
    the model identity when the pool is the *global paged* layout shared
    by engines serving different model shapes — such engines address one
    pool arena but keep distinct step symbols (a shared name with
    different step functions would silently run the first engine's model
    for everyone).

    ``temperature > 0`` builds the *sampled* decode step: its token
    operand is ``(toks, key)`` — the PRNG key threads as an operand, so
    the step stays pure and jit-cached — and next ids draw from the
    temperature-scaled, optionally top-k-truncated distribution.  The
    greedy default (``temperature=0``) compiles the exact argmax program
    of previous revisions, bit-identical, under the unsuffixed symbol
    names.
    """
    sk = step_key or pool_key

    def prefill_step(arena, pool, params, meta, batch, guard):
        cache = join_cache_pool(pool, meta)
        cache, logits = api.prefill(params, cache, batch, guard=guard)
        new_pool, new_meta = split_cache_pool(cache)
        return arena, new_pool, (
            new_meta, jnp.argmax(logits, -1).astype(jnp.int32))

    if temperature > 0:
        def decode_step(arena, pool, params, meta, x, guard):
            toks, key = x
            cache = join_cache_pool(pool, meta)
            cache, logits = api.decode(params, cache, toks, guard=guard)
            new_pool, new_meta = split_cache_pool(cache)
            logits = logits.astype(jnp.float32)
            if top_k:
                kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
                logits = jnp.where(logits < kth, -jnp.inf, logits)
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
            return arena, new_pool, (new_meta, nxt.astype(jnp.int32))
        decode_name = f"serve.decode.sampled[{sk}:t{temperature}:k{top_k}]"
    else:
        def decode_step(arena, pool, params, meta, toks, guard):
            cache = join_cache_pool(pool, meta)
            cache, logits = api.decode(params, cache, toks, guard=guard)
            new_pool, new_meta = split_cache_pool(cache)
            return arena, new_pool, (
                new_meta, jnp.argmax(logits, -1).astype(jnp.int32))
        decode_name = f"serve.decode[{sk}]"

    return TrustedStepBundle(
        pool_name=f"serve.pool[{pool_key}]",
        prefill_name=f"serve.prefill[{sk}]",
        decode_name=decode_name,
        prefill_fn=prefill_step,
        decode_fn=decode_step,
        verify=verify,
    )


# ---------------------------------------------------------------------------
# Elastic relocation steps (core/elastic.py)
# ---------------------------------------------------------------------------


def build_flat_relocation_step(moves: Tuple[Tuple[int, int, int], ...],
                               zeros: Tuple[Tuple[int, int], ...],
                               src_extent: Tuple[int, int],
                               dst_extent: Tuple[int, int]) -> Callable:
    """On-device compaction step for the flat arena — a *trusted* kernel
    (``fn(arena) -> (arena, None)``) the elastic manager registers and
    dispatches through the BatchedLaunchScheduler between drain cycles.

    ``moves`` are absolute ``(src, dst, len)`` slot copies, applied in
    order (the elastic planner emits them ascending with ``dst <= src``
    per move, so in-place packing never reads a clobbered source);
    ``zeros`` scrub the vacated ranges afterwards (no stale tenant bytes
    in reclaimed slots).  Reads are fenced against the tenant's source
    extent and writes against its destination extent, and the scrub
    ranges — static ints — are validated here against the union of the
    two extents before the step exists at all: the relocation step obeys
    the same bounds discipline as any tenant kernel, so a bug in the
    planner cannot touch a co-tenant's slots.
    """
    src_fp = FenceParams(base=src_extent[0], size=src_extent[1])
    dst_fp = FenceParams(base=dst_extent[0], size=dst_extent[1])
    for start, ln in zeros:
        in_src = (src_extent[0] <= start
                  and start + ln <= src_extent[0] + src_extent[1])
        in_dst = (dst_extent[0] <= start
                  and start + ln <= dst_extent[0] + dst_extent[1])
        if ln < 0 or not (in_src or in_dst):
            raise ValueError(
                f"relocation scrub range [{start},{start + ln}) leaves "
                f"the moving tenant's extents {src_extent}/{dst_extent}")

    def relocate(arena):
        from repro.core.fence import (
            guarded_dynamic_slice,
            guarded_dynamic_update_slice,
        )
        for src, dst, ln in moves:
            data = guarded_dynamic_slice(
                arena, jnp.int32(src), ln, src_fp, FencePolicy.BITWISE)
            arena = guarded_dynamic_update_slice(
                arena, jnp.int32(dst), data, dst_fp, FencePolicy.BITWISE)
        for start, ln in zeros:
            z = jnp.zeros((ln, *arena.shape[1:]), arena.dtype)
            arena = jax.lax.dynamic_update_slice_in_dim(
                arena, z, start, axis=0)
        return arena, None

    return relocate


def build_pool_relocation_step(src: int, dst: int, size: int) -> Callable:
    """Slot-extent move for a manager-owned serve pool — a trusted kernel
    with ``pool_arena`` threading (``fn(arena, pool) -> (arena, pool,
    None)``) so a tenant's KV/state slots follow its partition when the
    elastic manager grows or relocates it.

    Every slot-indexed pool tensor (axis 1 — see
    ``kvcache.PagedKVCache``) has ``[src, src+size)`` copied wholesale to
    ``[dst, dst+size)`` and the vacated source range zeroed; per-slot
    page tables live in the engines' meta halves and are slot-relative,
    so they survive the move untouched.  Distinct buddy extents never
    overlap (pow2 blocks nest or are disjoint), which makes
    copy-then-zero exact.
    """

    def move(arr):
        if arr.ndim < 2 or arr.shape[1] < max(src, dst) + size:
            # meta-shaped straggler: too short to be slot-indexed over
            # BOTH extents — touching it would clamp the copy into the
            # wrong rows, so it passes through untouched
            return arr
        data = jax.lax.dynamic_slice_in_dim(arr, src, size, axis=1)
        arr = jax.lax.dynamic_update_slice_in_dim(arr, data, dst, axis=1)
        z = jnp.zeros_like(data)
        return jax.lax.dynamic_update_slice_in_dim(arr, z, src, axis=1)

    def relocate(arena, pool):
        return arena, jax.tree.map(move, pool), None

    return relocate


def _cache_shape_for(api: ModelAPI, cfg: ModelConfig, shape: ShapeConfig,
                     kv_dtype: str = "bf16"):
    fam = cfg.family
    if fam == "ssm":
        return jax.eval_shape(
            functools.partial(api.init_cache, shape.global_batch))
    dt = _KV_DTYPES[kv_dtype]
    if fam == "encdec":
        return jax.eval_shape(functools.partial(
            api.init_cache, shape.global_batch, shape.seq_len, dtype=dt))
    return jax.eval_shape(functools.partial(
        api.init_cache, shape.global_batch, shape.seq_len, dtype=dt))


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                       guard_enabled: bool = True,
                       policy: FencePolicy = FencePolicy.BITWISE,
                       kv_dtype: str = "bf16") -> StepBundle:
    api = get_model(cfg)
    rules = make_rules(mesh, fsdp=False)   # serving: weights TP-only
    guard = make_guard(cfg, shape, policy, guard_enabled)

    def prefill_step(params, cache, batch):
        cache, logits = api.prefill(params, cache, batch, guard=guard,
                                    rules=rules)
        return cache, logits

    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    cache_shape = _cache_shape_for(api, cfg, shape, kv_dtype)
    batch_specs = api.batch_specs(shape)

    p_shard = _shard_tree(mesh, rules, api.param_logical_axes(),
                          params_shape)
    c_shard = _shard_tree(mesh, rules, cache_axes(mesh, cfg, cache_shape),
                          cache_shape)
    b_shard = _shard_tree(mesh, rules, _batch_axes(batch_specs),
                          batch_specs)
    logits_shard = _shard_tree(mesh, rules, ("batch", "vocab"),
                               jax.ShapeDtypeStruct(
                                   (shape.global_batch, cfg.vocab),
                                   jnp.float32))

    return StepBundle(
        fn=prefill_step,
        in_specs=(params_shape, cache_shape, batch_specs),
        in_shardings=(p_shard, c_shard, b_shard),
        out_shardings=(c_shard, logits_shard),
        donate_argnums=(1,),
        rules=rules,
        api=api,
    )


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                      guard_enabled: bool = True,
                      policy: FencePolicy = FencePolicy.BITWISE,
                      kv_dtype: str = "bf16") -> StepBundle:
    api = get_model(cfg)
    rules = make_rules(mesh, fsdp=False)
    guard = make_guard(cfg, shape, policy, guard_enabled)

    def decode_step(params, cache, tokens):
        cache, logits = api.decode(params, cache, tokens, guard=guard,
                                   rules=rules)
        return cache, logits

    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    cache_shape = _cache_shape_for(api, cfg, shape, kv_dtype)
    tokens_spec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)

    p_shard = _shard_tree(mesh, rules, api.param_logical_axes(),
                          params_shape)
    c_shard = _shard_tree(mesh, rules, cache_axes(mesh, cfg, cache_shape),
                          cache_shape)
    t_shard = _shard_tree(mesh, rules, ("batch",), tokens_spec)
    logits_shard = _shard_tree(mesh, rules, ("batch", "vocab"),
                               jax.ShapeDtypeStruct(
                                   (shape.global_batch, cfg.vocab),
                                   jnp.float32))

    return StepBundle(
        fn=decode_step,
        in_specs=(params_shape, cache_shape, tokens_spec),
        in_shardings=(p_shard, c_shard, t_shard),
        out_shardings=(c_shard, logits_shard),
        donate_argnums=(1,),
        rules=rules,
        api=api,
    )


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    kw.pop("moe_dispatch", None)
    kw.pop("remat_policy", None)
    if shape.kind == "train":
        kw.pop("kv_dtype", None)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, **kw)
    return build_decode_step(cfg, shape, mesh, **kw)
