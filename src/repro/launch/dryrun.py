import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, record memory/cost analysis + roofline terms.

MUST be run as its own process (one cell per process is the default; the
--all driver spawns subprocesses) because jax locks the device count at
first init — hence the XLA_FLAGS assignment above, before any other
import.

Usage:
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all            # every applicable cell
    python -m repro.launch.dryrun --all --multi-pod

Each cell writes results/dryrun/<mesh>/<arch>__<shape>.json with:
    memory_analysis, trip-count-aware HLO cost analysis (flops / bytes /
    collective bytes — see ``hlo_analysis``; XLA's own cost_analysis
    counts scan bodies once and is kept only as a cross-check), the three
    roofline terms, MODEL_FLOPS and the useful-compute ratio (§Roofline).
"""

import argparse
import json
import sys
import time
from typing import Optional

# TPU v5e hardware constants (assignment §Roofline)
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun",
             guard_enabled: bool = True,
             policy_name: str = "bitwise",
             tag: str = "",
             moe_dispatch: str = "einsum",
             remat_policy: str = "nothing",
             kv_dtype: str = "bf16") -> Optional[dict]:
    import jax
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.core.fence import FencePolicy
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        print(f"SKIP {arch} x {shape_name}: {why}")
        return None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    kw = {}
    if shape.kind != "train":
        kw["kv_dtype"] = kv_dtype
    bundle = build_step(cfg, shape, mesh, guard_enabled=guard_enabled,
                        policy=FencePolicy(policy_name),
                        moe_dispatch=moe_dispatch,
                        remat_policy=remat_policy, **kw)
    jitted = jax.jit(bundle.fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    # the mesh context makes bare-PartitionSpec sharding constraints
    # (loop-carry pins inside flash attention etc.) bind to this mesh
    with mesh:
        lowered = jitted.lower(*bundle.in_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.launch.hlo_analysis import analyze_hlo

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # jax<=0.4.x: one dict per program
        xla_cost = xla_cost[0] if xla_cost else {}
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo)

    flops_dev = float(costs.flops)
    bytes_dev = float(costs.bytes)
    coll_dev = float(costs.collective_bytes)
    coll = {"per_kind": {k: v for k, v in costs.collectives.items() if v},
            "counts": {k: v for k, v in costs.collective_counts.items()
                       if v},
            "total": coll_dev}

    compute_term = flops_dev / PEAK_FLOPS
    memory_term = bytes_dev / HBM_BW
    collective_term = coll_dev / ICI_BW
    terms = {"compute_s": compute_term, "memory_s": memory_term,
             "collective_s": collective_term}
    bottleneck = max(terms, key=terms.get)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    hlo_total_flops = flops_dev * chips
    useful_ratio = model_flops / hlo_total_flops if hlo_total_flops else 0.0
    # roofline fraction: useful model FLOPs per second achievable given the
    # dominant term, relative to pure-compute peak
    step_time = max(terms.values())
    mfu = (model_flops / chips / step_time) / PEAK_FLOPS if step_time else 0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "guard": guard_enabled,
        "policy": policy_name,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_live_bytes_per_device":
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "vector_flops_per_device": float(costs.vector_flops),
                 "bytes_per_device": bytes_dev,
                 "xla_flops_scan_body_once":
                     float(xla_cost.get("flops", 0.0)),
                 "xla_bytes_scan_body_once":
                     float(xla_cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": {
            **terms,
            "bottleneck": bottleneck.replace("_s", ""),
            "model_flops": model_flops,
            "hlo_total_flops": hlo_total_flops,
            "useful_ratio": useful_ratio,
            "roofline_fraction_mfu": mfu,
        },
        "params": {"total": n_params, "active": n_active},
    }

    os.makedirs(f"{out_dir}/{result['mesh']}", exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = f"{out_dir}/{result['mesh']}/{arch}__{shape_name}{suffix}.json"
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"OK   {arch:22s} x {shape_name:12s} mesh={result['mesh']} "
          f"compile={t_compile:6.1f}s flops/dev={flops_dev:.3e} "
          f"bottleneck={result['roofline']['bottleneck']} "
          f"mfu={mfu:.3f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-guard", action="store_true",
                    help="native fast-path (no fence instructions)")
    ap.add_argument("--policy", default="bitwise",
                    choices=["bitwise", "modulo", "check", "none"])
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "scatter"],
                    help="MoE dispatch impl (einsum=paper-simple baseline, "
                         "scatter=optimized)")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "f32", "f8"])
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    if args.all:
        # one subprocess per cell: fresh jax, bounded memory
        import subprocess
        from repro.configs import SHAPES, get_config, list_archs, \
            shape_applicable
        failures = []
        for arch in list_archs():
            for shape_name in SHAPES:
                cfg = get_config(arch)
                ok, _ = shape_applicable(cfg, SHAPES[shape_name])
                if not ok:
                    continue
                mesh_tag = "2x16x16" if args.multi_pod else "16x16"
                suffix = f"__{args.tag}" if args.tag else ""
                path = (f"{args.out_dir}/{mesh_tag}/"
                        f"{arch}__{shape_name}{suffix}.json")
                if args.skip_done and os.path.exists(path):
                    print(f"SKIP (done) {arch} x {shape_name}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out-dir", args.out_dir]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.no_guard:
                    cmd.append("--no-guard")
                if args.policy != "bitwise":
                    cmd += ["--policy", args.policy]
                if args.moe_dispatch != "einsum":
                    cmd += ["--moe-dispatch", args.moe_dispatch]
                if args.kv_dtype != "bf16":
                    cmd += ["--kv-dtype", args.kv_dtype]
                if args.remat_policy != "nothing":
                    cmd += ["--remat-policy", args.remat_policy]
                if args.tag:
                    cmd += ["--tag", args.tag]
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((arch, shape_name))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    run_cell(args.arch, args.shape, args.multi_pod,
             out_dir=args.out_dir,
             guard_enabled=not args.no_guard,
             policy_name=args.policy, tag=args.tag,
             moe_dispatch=args.moe_dispatch,
             remat_policy=args.remat_policy,
             kv_dtype=args.kv_dtype)


if __name__ == "__main__":
    main()
