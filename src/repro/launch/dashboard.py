"""Terminal rendering of the Guardian flight recorder — the formatting
half of ``python -m repro.top``.

Pure string assembly over the :meth:`GuardianManager.metrics_report`
dict (plus, optionally, the live :class:`MetricsRegistry` for bucket
sparklines).  No jax, no curses, no device access — unit-tested in
tests/test_telemetry.py against canned report dicts.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

#: eight-level unicode bars, lowest to highest
SPARK_CHARS = "▁▂▃▄▅▆▇█"

WIDTH = 72


def sparkline(values: Iterable[float]) -> str:
    """One character per value, scaled to the series max (all-zero and
    empty series render flat)."""
    vals = [max(float(v), 0.0) for v in values]
    if not vals:
        return ""
    top = max(vals)
    if top <= 0:
        return SPARK_CHARS[0] * len(vals)
    n = len(SPARK_CHARS)
    return "".join(
        SPARK_CHARS[min(int(v / top * (n - 1) + 0.5), n - 1)]
        for v in vals)


def _us(v: float) -> str:
    """Humanized microseconds."""
    if v < 1000:
        return f"{v:.0f}us"
    if v < 1e6:
        return f"{v / 1e3:.1f}ms"
    return f"{v / 1e6:.2f}s"


def _bytes(v: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if v < 1024 or unit == "GB":
            return f"{v:.0f}{unit}" if unit == "B" else f"{v:.1f}{unit}"
        v /= 1024
    return f"{v:.1f}GB"      # pragma: no cover


def _rule(title: str) -> str:
    pad = WIDTH - len(title) - 4
    return f"── {title} {'─' * max(pad, 2)}"


def _pcts(d: Dict[str, float], unit: str = "") -> str:
    fmt = _us if unit == "us" else (lambda x: f"{x:g}")
    return (f"p50 {fmt(d.get('p50', 0.0))}  p90 {fmt(d.get('p90', 0.0))}"
            f"  p99 {fmt(d.get('p99', 0.0))}"
            f"  (n={int(d.get('count', 0))})")


def format_tenants(report: Dict[str, Any]) -> List[str]:
    lines = [f"{'tenant':<18}{'state':<12}{'policy':<9}{'cls':>4}{'wt':>3}"
             f"{'extent':>15}{'util':>6}{'infl':>5}{'pg%':>5}"
             f"{'q50':>5}{'q99':>5}"
             f"{'e2e50':>8}{'e2e99':>8}{'slo%':>6}{'viol':>6}"]
    short_cls = {"latency_critical": "lc", "best_effort": "be"}
    for name, row in sorted(report.get("tenants", {}).items()):
        part = row.get("partition", {})
        extent = f"[{part.get('base', 0)},{part.get('base', 0) + part.get('size', 0)})"
        util = row.get("utilization")
        infl = row.get("inflight")
        pg = row.get("page_occupancy")
        age = row.get("queue_age", {})
        cls = short_cls.get(row.get("class"), "-")
        # request-span ledger columns: end-to-end latency percentiles
        # and SLO attainment (dashes for tenants that never served)
        lat = row.get("latency", {})
        slo = row.get("slo", {})
        served = slo.get("attained", 0) + slo.get("violated", 0)
        e50 = _us(lat["p50"]) if lat.get("count") else "-"
        e99 = _us(lat["p99"]) if lat.get("count") else "-"
        att = f"{slo.get('attained', 0) / served:.0%}" if served else "-"
        lines.append(
            f"{name:<18}{row.get('state', '?'):<12}"
            f"{row.get('policy', '?'):<9}{cls:>4}{row.get('weight', 1):>3}"
            f"{extent:>15}"
            f"{('-' if util is None else f'{util:.2f}'):>6}"
            f"{('-' if infl is None else f'{int(infl)}'):>5}"
            f"{('-' if pg is None else f'{pg:.0%}'):>5}"
            f"{age.get('p50', 0.0):>5g}{age.get('p99', 0.0):>5g}"
            f"{e50:>8}{e99:>8}{att:>6}"
            f"{row.get('violations', {}).get('total', 0):>6}")
    return lines


def format_report(report: Dict[str, Any],
                  registry: Any = None) -> str:
    """Render one metrics_report() snapshot as a terminal dashboard.

    ``registry`` (the live :class:`MetricsRegistry`, optional) adds
    bucket sparklines for the drain-cycle and fused-width histograms —
    the report dict alone carries only their percentiles.
    """
    sched = report.get("scheduler", {})
    drain = report.get("drain", {})
    jc = report.get("jit_cache", {})
    el = report.get("elastic", {})
    mem = report.get("memory", {})
    launch = report.get("launch", {})
    trace = report.get("trace", {})
    vio = report.get("violations", {})
    slo = report.get("slo", {})

    lines: List[str] = [
        f"guardian flight recorder — {len(report.get('tenants', {}))} "
        f"tenant(s), {report.get('drain_cycles', 0)} drain cycle(s)",
        _rule("tenants"),
        *format_tenants(report),
        _rule("scheduler"),
        (f"launches {int(sched.get('total_launches', 0))}"
         f"  device steps {int(sched.get('device_steps', 0))}"
         f" (fused {int(sched.get('fused_steps', 0))},"
         f" check {int(sched.get('check_steps', 0))},"
         f" proven {int(sched.get('proven_steps', 0))})"
         f"  mean width {sched.get('mean_batch_width', 0.0):.1f}"
         f"  max {int(sched.get('max_batch_width', 0))}"),
        (f"queue age   {_pcts(sched.get('queue_age', {}))} cycles"
         f"   lookahead fused {int(sched.get('lookahead_fused', 0))},"
         f" budget {int(sched.get('lookahead_budget', 0))}"
         f"   be preempts {int(sched.get('be_preemptions', 0))}"),
        *(f"  {cls:<18}{_pcts(p)} cycles"
          for cls, p in sorted(
              sched.get("queue_age_by_class", {}).items())),
        f"fused width {_pcts(sched.get('fused_width', {}))}",
        _rule("drain cycles"),
        f"wall time   {_pcts(drain, unit='us')}",
    ]
    if registry is not None:
        h = registry.histogram("drain_cycle_us")
        if h is not None:
            lines.append(f"buckets     {sparkline(h.buckets)}  "
                         f"({_us(h.bounds[0])}..{_us(h.bounds[-1])}+)")
        w = registry.histogram("fused_step_width")
        if w is not None:
            lines.append(f"widths      {sparkline(w.buckets)}  "
                         f"({w.bounds[0]:g}..{w.bounds[-1]:g}+)")
    lines += [
        _rule("jit cache"),
        (f"kernel entries {jc.get('entries', 0)}/{jc.get('capacity', 0)}"
         f" (evictions {jc.get('evictions', 0)})"
         f"   fused {jc.get('fused_entries', 0)}/"
         f"{jc.get('fused_capacity', 0)}"
         f" (evictions {jc.get('fused_evictions', 0)})"),
        _rule("elastic"),
        (f"admitted {el.get('admitted', 0)}"
         f"  waitlisted {el.get('waitlisted', 0)}"
         f" ({el.get('waitlist', 0)} waiting)"
         f"  grows {el.get('grows', 0)}  shrinks {el.get('shrinks', 0)}"
         f"  relocations {el.get('relocations', 0)}"
         f"  compactions {el.get('compactions', 0)}"),
        f"waitlist age {_pcts(el.get('waitlist_age', {}))} cycles",
        _rule("memory"),
        (f"arena {_bytes(mem.get('arena_bytes', 0))}"
         f"  free slots {mem.get('free_slots', 0)}"
         f"  live: " + (", ".join(
             f"{t}={n}" for t, n in sorted(
                 mem.get("tenant_live_slots", {}).items())) or "-")),
        _rule("launch path"),
        (f"lookup {launch.get('lookup_ns', 0.0):.0f}ns"
         f"  augment {launch.get('augment_ns', 0.0):.0f}ns"
         f"  dispatch {launch.get('dispatch_ns', 0.0):.0f}ns"),
        _rule("violations"),
        (f"transfer {len(vio.get('transfer_violations', []))}"
         f"  quarantine events {len(vio.get('events', []))}"),
        _rule("slo ledger"),
        (f"requests: {slo.get('completed', 0)} completed"
         f"  {slo.get('evicted', 0)} evicted"
         f"  {slo.get('withdrawn', 0)} withdrawn"
         f"  ({slo.get('open_spans', 0)} spans open)"),
        *(f"  {cls:<18}attained {row.get('attained', 0)}"
          f"  violated {row.get('violated', 0)}"
          f"  ({row.get('attainment', 1.0):.1%})"
          + ("  causes: " + ",".join(
              f"{c}={n}" for c, n in sorted(
                  row.get("causes", {}).items()))
             if row.get("causes") else "")
          for cls, row in sorted(slo.get("classes", {}).items())),
        _rule("trace"),
        (f"{trace.get('events', 0)} event(s) buffered"
         f" ({trace.get('emitted', 0)} emitted,"
         f" capacity {trace.get('capacity', 0)})"
         + (f"  ! {trace.get('dropped', 0)} dropped (ring overflow — "
            f"raise trace_capacity)"
            if trace.get("dropped", 0) else "")),
    ]
    return "\n".join(lines)
