"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` dance.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: meshes are implicitly Auto-typed
    AxisType = None


def _make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over whatever single device is present (CPU smoke)."""
    return _make_mesh((1, 1), ("data", "model"))


def describe(mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} = "
            f"{mesh.devices.size} devices")
