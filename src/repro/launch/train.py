"""Training driver — fault-tolerant, restart-exact, multi-host ready.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 300 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Features exercised end-to-end (scaled to this container on --reduced):
* deterministic sharded data (restart reproduces the exact token stream)
* AdamW + cosine/WSD schedule, global-norm clipping
* atomic async checkpointing + ``--resume`` auto-restart
* per-step watchdog (straggler detection: a step exceeding
  ``--straggler-factor`` x the trailing median is logged and counted —
  on a real pod this triggers the backup-replica path)
* preemption hook (SIGTERM -> final checkpoint -> clean exit)
* Guardian fencing on the training data paths (--guard / --no-guard)
"""

from __future__ import annotations

import argparse
import json
import signal
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--no-guard", action="store_true")
    ap.add_argument("--policy", default="bitwise",
                    choices=["bitwise", "modulo", "check"])
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate preemption: checkpoint and exit after "
                         "this step (schedule still spans --steps)")
    args = ap.parse_args()

    from repro.checkpoint import CheckpointStore
    from repro.configs import ShapeConfig, get_config
    from repro.core.fence import FencePolicy
    from repro.data import DataConfig, make_source
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_guard
    from repro.models import get_model
    from repro.optim import adamw, apply_updates, constant, cosine, wsd

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    mesh = make_local_mesh()
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    guard = make_guard(cfg, shape, FencePolicy(args.policy),
                       enabled=not args.no_guard)

    sched = {"cosine": lambda: cosine(args.lr, args.steps // 10,
                                      args.steps),
             "wsd": lambda: wsd(args.lr, args.steps // 10,
                                int(args.steps * 0.7),
                                args.steps - args.steps // 10
                                - int(args.steps * 0.7)),
             "constant": lambda: constant(args.lr)}[args.schedule]()
    opt = adamw(sched)

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed,
                          host_index=jax.process_index(),
                          host_count=jax.process_count())
    source = make_source(data_cfg)

    params = api.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start_step = 0

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    if store and args.resume and store.latest_step() is not None:
        (params, opt_state), start_step = store.restore(
            (params, opt_state))
        print(f"resumed from step {start_step}")

    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_of(p):
            return api.loss(p, batch, guard=guard, remat=False)
        loss, grads = jax.value_and_grad(loss_of)(params)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2, loss

    # preemption hook: checkpoint on SIGTERM, then exit cleanly
    preempted = {"flag": False}

    def on_sigterm(_sig, _frm):
        preempted["flag"] = True
    signal.signal(signal.SIGTERM, on_sigterm)

    times, stragglers = [], 0
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v)
                 for k, v in source.batch(step).items()}
        params, opt_state, loss = train_step(params, opt_state, batch)
        loss = float(loss)
        dt = time.time() - t0
        times.append(dt)
        losses.append(loss)
        if len(times) > 10:
            med = statistics.median(times[-50:])
            if dt > args.straggler_factor * med:
                stragglers += 1
                print(f"[watchdog] step {step} took {dt:.3f}s "
                      f"(median {med:.3f}s) — straggler #{stragglers}")
        if step % args.log_every == 0:
            print(f"step {step:6d} loss {loss:.4f} "
                  f"({dt * 1e3:.0f} ms/step)")
        if store and (step + 1) % args.ckpt_every == 0:
            store.save_async(step + 1, (params, opt_state))
        if preempted["flag"] or (args.stop_after
                                 and step + 1 >= args.stop_after):
            print(f"[preemption] stopping at step {step + 1} — "
                  "checkpointing")
            if store:
                store.wait()
                store.save(step + 1, (params, opt_state))
            summary = {"final_loss": losses[-1], "first_loss": losses[0],
                       "steps": len(losses), "stragglers": stragglers,
                       "preempted_at": step + 1}
            print(json.dumps(summary))
            sys.exit(0)
    if store:
        store.wait()
        store.save(args.steps, (params, opt_state))
    summary = {"final_loss": losses[-1] if losses else None,
               "first_loss": losses[0] if losses else None,
               "steps": len(losses), "stragglers": stragglers}
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
