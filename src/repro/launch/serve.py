"""Multi-tenant serving engine — Guardian's spatial sharing applied to a
shared LM server, **unified with the GuardianManager launch path**.

One model, one KV pool, many mutually-untrusting tenants.  The pool's
sequence-slot space is carved into contiguous pow2 partitions by the
engine's :class:`~repro.core.manager.GuardianManager` (the same buddy
allocator, bounds table and quarantine lifecycle that fence raw kernel
launches).  The engine owns **no fence table and no row-assignment
policy of its own**:

* every prefill/decode step is registered as a *trusted kernel* and
  submitted as a :class:`~repro.core.scheduler.LaunchRequest`, enqueued
  and drained by the shared :class:`BatchedLaunchScheduler` — serving
  traffic and raw tenant launches ride one dispatch layer;
* per-row fence params come from :meth:`GuardianManager.fence_table`
  (bitwise rows + the MODULO magic row table), gathered through a
  tenant-id column: batch row b belongs to tenant t(b), so the slot index
  of row b is fenced with t(b)'s bounds.  Even a corrupted scheduler or a
  forged slot id can only wrap inside the owning tenant's slots;
* batch-row selection uses the scheduler's shared
  :func:`~repro.core.scheduler.round_robin_interleave` fairness policy;
* tenants may carry **per-tenant fence policies** (a CHECK canary beside
  MODULO production tenants): the step gathers a per-row policy-code
  column and dispatches per element (``fence.apply_fence_mixed``);
* CHECK rows attribute: their ``ok`` predicates are collected per step
  and folded into the manager's ViolationLog, so a tenant spraying
  out-of-partition slot ids is quarantined by the same
  :class:`~repro.core.quarantine.QuarantineManager` poll that polices raw
  launches — and manager-side transitions propagate *back* into the
  engine through the quarantine subscription (pending requests dropped,
  pool slots scrubbed on eviction).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --tenants 3 --requests 6 --tokens 16 \
        --policies modulo,check
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.fence import FenceParams, FencePolicy
from repro.core.manager import GuardianManager
from repro.core.quarantine import QuarantinePolicy, TenantState
from repro.core.scheduler import round_robin_interleave
from repro.core.violations import NUM_KINDS, ViolationKind
from repro.models import get_model
from repro.models.guard import GuardSpec

#: The engine's own manager tenant: owns the scratch half of the pool where
#: idle batch rows park (their fenced writes must never land in a tenant's
#: slots) and is the tenant id under which step launches are enqueued.
ENGINE_TENANT = "__scratch"


@dataclasses.dataclass
class Request:
    tenant: str
    rid: int
    prompt: np.ndarray
    slot: int                      # absolute slot in the shared pool
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching (fixed-slot) multi-tenant server.

    A thin client of its :class:`GuardianManager`: request bookkeeping and
    operand marshalling live here; partitioning, fencing rows, launch
    scheduling and quarantine all live on the manager side.
    """

    def __init__(self, cfg, *, max_batch: int = 8, max_len: int = 256,
                 policy: FencePolicy = FencePolicy.BITWISE,
                 guard: bool = True, seed: int = 0,
                 quarantine_policy: Optional[QuarantinePolicy] = None):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.policy = policy
        self.guard_enabled = guard
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = self.api.init(jax.random.PRNGKey(seed))
        # pool = 2x the batch slots: the upper half is the engine's scratch
        # partition where idle batch rows park.
        n_slots = 2 * _pow2(max_batch)
        if cfg.family == "ssm":
            self.cache = self.api.init_cache(max_batch, slots=n_slots)
        else:
            self.cache = self.api.init_cache(max_batch, max_len,
                                             dtype=jnp.float32,
                                             slots=n_slots)
        slots = self._pool_slots()
        # The manager owns the pool's partitioning and the launch path.
        # standalone_fast_path=False: a guarded engine always fences, even
        # with a single tenant (bit-identical generations solo vs shared).
        self.manager = GuardianManager(
            total_slots=slots, policy=policy,
            standalone_fast_path=False,
            quarantine_policy=quarantine_policy)
        self._client = self.manager.register_tenant(ENGINE_TENANT,
                                                    slots // 2)
        self._scratch = self.manager.bounds.lookup(ENGINE_TENANT)
        self.manager.quarantine.subscribe(self._on_transition)
        self._register_step_kernels()
        self.rejected: List[int] = []     # rids dropped by quarantine
        self._requests: List[Request] = []
        self._rid = 0
        self.decode_steps = 0
        # evictions fired *during* run() scrub the stale self.cache; the
        # live local cache is re-scrubbed at run()-end from this list
        self._in_run = False
        self._pending_scrubs: List[tuple] = []

    def _pool_slots(self) -> int:
        c = self.cache
        if hasattr(c, "k"):
            return c.k.shape[1]
        if hasattr(c, "pools"):
            return next(iter(c.pools.values())).shape[1]
        return c.kv.k.shape[1]

    def _register_step_kernels(self) -> None:
        """The engine's steps as trusted manager kernels: internally fenced
        (per-row GuardSpec from the manager's fence table), executed
        eagerly by the per-launch path, enqueued/drained like any launch.
        The flat manager arena is threaded untouched — the serve pool
        tensors ride in the operands and return through the result."""
        api, params = self.api, self.params

        def prefill_step(arena, cache, batch, guard):
            return arena, api.prefill(params, cache, batch, guard=guard)

        def decode_step(arena, cache, toks, guard):
            return arena, api.decode(params, cache, toks, guard=guard)

        self.manager.register_trusted_kernel("serve.prefill", prefill_step)
        self.manager.register_trusted_kernel("serve.decode", decode_step)

    # ------------------------------------------------------------------ #
    # Tenant lifecycle (all state on the manager)                        #
    # ------------------------------------------------------------------ #
    @property
    def bounds(self):
        return self.manager.bounds

    @property
    def quarantine(self):
        """The shared lifecycle driver (manager-owned)."""
        return self.manager.quarantine

    def register_tenant(self, name: str, slots: int,
                        policy: Optional[FencePolicy] = None):
        """Carve a pool partition for ``name``; returns the Partition.

        ``policy`` optionally overrides the engine default for this
        tenant's rows (per-row mixed fencing)."""
        self.manager.register_tenant(name, slots, policy=policy)
        return self.manager.bounds.lookup(name)

    def quarantine_tenant(self, name: str, reason: str = "") -> List[int]:
        """Reject the tenant via the manager's quarantine (the subscription
        drops its pending requests; new submissions raise).  Returns the
        dropped request ids."""
        before = len(self.rejected)
        self.manager.quarantine.quarantine(name, reason=reason)
        return self.rejected[before:]

    def evict_tenant(self, name: str) -> None:
        """Scrub the tenant's pool slots and return its partition to the
        buddy allocator (manager-side reclamation; the subscription scrubs
        the serve pool while the bounds are still resolvable)."""
        self.manager.quarantine.evict(name)

    def readmit_tenant(self, name: str) -> None:
        self.manager.quarantine.readmit(name)

    def _on_transition(self, tenant_id: str, state: TenantState) -> None:
        """Manager-side quarantine events propagate into the serving plane
        (including transitions the engine never initiated, e.g. a
        ViolationLog threshold crossing from raw-launch traffic)."""
        if tenant_id == ENGINE_TENANT:
            return
        if state is TenantState.EVICTED:
            # fires before partition reclamation: bounds still resolvable
            part = self.manager.bounds.lookup(tenant_id)
            self.cache = _scrub_slots(self.cache, part.base, part.size)
            if self._in_run:
                # run() holds a newer local cache that will overwrite
                # self.cache at run-end — it must be scrubbed too, or the
                # evicted tenant's KV leaks into the reclaimed partition
                self._pending_scrubs.append((part.base, part.size))
        if not state.admissible:
            dropped = [r.rid for r in self._requests
                       if r.tenant == tenant_id and not r.done]
            self._requests = [r for r in self._requests
                              if r.done or r.tenant != tenant_id]
            self.rejected.extend(dropped)

    def submit(self, tenant: str, prompt: np.ndarray) -> int:
        self.manager.quarantine.check_admission(tenant, "submit")
        part = self.manager.bounds.lookup(tenant)
        used = {r.slot for r in self._requests if not r.done
                and r.tenant == tenant}
        free = [s for s in range(part.base, part.end) if s not in used]
        if not free:
            raise RuntimeError(f"tenant {tenant}: no free slots")
        rid = self._rid
        self._rid += 1
        self._requests.append(Request(tenant=tenant, rid=rid,
                                      prompt=np.asarray(prompt),
                                      slot=free[0]))
        return rid

    # ------------------------------------------------------------------ #
    def _guard_for_rows(self, rows: List[Optional[Request]]
                        ) -> Optional[GuardSpec]:
        if not self.guard_enabled:
            return None
        table, row_of = self.manager.fence_table()
        # tenant-id column: batch row b -> fence-table row of its tenant
        # (idle rows park in the engine's scratch partition)
        cols = np.full((self.max_batch,), row_of[ENGINE_TENANT], np.int32)
        pol = np.full((self.max_batch,), self.policy.code, np.int32)
        for i, r in enumerate(rows):
            if r is not None:
                cols[i] = row_of[r.tenant]
                pol[i] = self.manager.policy_of(r.tenant).code
        slot_params = table.gather(jnp.asarray(cols))
        # row-mixed policies only when some tenant actually diverges from
        # the engine default (the homogeneous path stays bit-identical)
        mixed = bool((pol != self.policy.code).any())
        row_policy = jnp.asarray(pol) if mixed else None
        pages = self.cache.kv.pages_per_slot if hasattr(self.cache, "kv") \
            else (self.cache.pages_per_slot if hasattr(self.cache, "k")
                  else 1)
        return GuardSpec(
            policy=self.policy,
            vocab=FenceParams(base=0, size=_pow2(self.cfg.vocab)),
            kv=slot_params,
            state=slot_params,
            expert=(FenceParams(base=0, size=_pow2(
                self.cfg.moe.num_experts)) if self.cfg.moe else None),
            page=FenceParams(base=0, size=_pow2(max(pages, 1))),
            row_policy=row_policy,
        )

    def _select_rows(self) -> List[Request]:
        """Batch-row assignment through the scheduler's shared round-robin
        fairness policy (§4.2.4).  Quarantined tenants' requests never
        occupy a row — their slots re-route to admissible co-tenants."""
        by_tenant: Dict[str, List[Request]] = {}
        for r in self._requests:
            if r.done:
                continue
            state = self.manager.quarantine.state_of(r.tenant)
            if state is None or state.admissible:
                by_tenant.setdefault(r.tenant, []).append(r)
        return round_robin_interleave(by_tenant, self.max_batch)

    def _attribute(self, rows: List[Request],
                   slot_ids: np.ndarray) -> None:
        """Per-step CHECK attribution for the serving plane: a CHECK row
        whose slot id left its owner's partition is a detected violation.

        Computed host-side from the same bounds the in-step fence used
        (the clamp happens on device; detection must not depend on model
        internals — slot fences run inside scan-over-layers).  One GATHER
        count per offending row per step, folded into the manager's
        ViolationLog so serve traffic feeds the same QuarantineManager
        poll as raw launches."""
        if not self.guard_enabled:
            return
        for i, r in enumerate(rows):
            state = self.manager.quarantine.state_of(r.tenant)
            if state is not None and not state.admissible:
                # quarantined/evicted mid-run: the row is a lame duck —
                # its bounds/log row may already be reclaimed
                continue
            if self.manager.policy_of(r.tenant) is not FencePolicy.CHECK:
                continue
            part = self.manager.bounds.lookup(r.tenant)
            if not (part.base <= int(slot_ids[i]) < part.end):
                counts = np.zeros((NUM_KINDS,), np.int32)
                counts[int(ViolationKind.GATHER)] = 1
                self.manager.violog.add(r.tenant, counts)

    # ------------------------------------------------------------------ #
    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Prefill all pending, then decode until done/limit.  Every step
        is a LaunchRequest drained by the manager's scheduler."""
        rows = self._select_rows()
        if not rows:
            return {}
        self._in_run = True
        try:
            return self._run_rows(rows, max_new_tokens)
        finally:
            self._in_run = False

    def _run_rows(self, rows: List[Request],
                  max_new_tokens: int) -> Dict[int, List[int]]:
        B = self.max_batch
        # build padded prompt batch
        plen = max(len(r.prompt) for r in rows)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(rows):
            toks[i, :len(r.prompt)] = r.prompt
        slot_ids = np.full((B,), self._scratch.base, np.int32)
        for i, r in enumerate(rows):
            slot_ids[i] = r.slot
        cache = dataclasses.replace(
            self._cache_with_slots(jnp.asarray(slot_ids)))
        guard = self._guard_for_rows(rows + [None] * (B - len(rows)))

        if self.cfg.family == "encdec":
            batch = {"src": jnp.zeros(
                (B, 16, self.cfg.d_model), jnp.float32),
                "tgt": jnp.asarray(toks)}
        else:
            batch = {"tokens": jnp.asarray(toks)}

        cache, logits = self._step("serve.prefill", (cache, batch, guard),
                                   rows, slot_ids)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(max_new_tokens):
            for i, r in enumerate(rows):
                r.generated.append(int(nxt[i]))
            cache, logits = self._step("serve.decode", (cache, nxt, guard),
                                       rows, slot_ids)
            self.decode_steps += 1
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.cache = cache
        # a mid-run eviction scrubbed the stale cache; re-apply to the one
        # we just committed (zeroing is idempotent, nothing re-registers
        # inside a single-threaded run)
        for base, size in self._pending_scrubs:
            self.cache = _scrub_slots(self.cache, base, size)
        self._pending_scrubs.clear()
        # rows whose tenant was quarantined/evicted mid-run were already
        # dropped + recorded in self.rejected: they must not also be
        # reported as served (their clamped generations are discarded)
        out: Dict[int, List[int]] = {}
        for r in rows:
            state = self.manager.quarantine.state_of(r.tenant)
            if state is None or state.admissible:
                r.done = True
                out[r.rid] = r.generated
        return out

    def _step(self, kernel: str, args, rows: List[Request],
              slot_ids: np.ndarray):
        """One engine step through the unified path: attribute CHECK rows,
        enqueue the launch, drain the manager (scheduler flush + the
        quarantine poll that consumes the attribution), read the result
        handle."""
        self._attribute(rows, slot_ids)
        req = self._client.launch_kernel(kernel, args=args)
        self.manager.run_queued()
        return req.result

    def _cache_with_slots(self, slot_ids):
        c = self.cache
        if hasattr(c, "slot_ids"):
            return dataclasses.replace(c, slot_ids=slot_ids)
        if hasattr(c, "kv"):   # hybrid / encdec
            kv = dataclasses.replace(c.kv, slot_ids=slot_ids)
            if hasattr(c, "state"):
                st = dataclasses.replace(c.state, slot_ids=slot_ids)
                return dataclasses.replace(c, kv=kv, state=st)
            return dataclasses.replace(c, kv=kv)
        return c


def _pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1


def _scrub_slots(cache, base: int, size: int):
    """Zero a slot range [base, base+size) across every pool tensor of a
    cache pytree (axis 1 is the shared slot axis in all cache layouts —
    see kvcache.PagedKVCache / StateCache)."""
    def zero(arr):
        z = jnp.zeros((arr.shape[0], size, *arr.shape[2:]), arr.dtype)
        return jax.lax.dynamic_update_slice_in_dim(arr, z, base, axis=1)

    if hasattr(cache, "kv"):          # hybrid / encdec: recurse
        new = {"kv": _scrub_slots(cache.kv, base, size)}
        if hasattr(cache, "state"):
            new["state"] = _scrub_slots(cache.state, base, size)
        if hasattr(cache, "cross_k"):  # encdec cross-attention pools
            new["cross_k"] = zero(cache.cross_k)
            new["cross_v"] = zero(cache.cross_v)
        return dataclasses.replace(cache, **new)
    if hasattr(cache, "pools"):
        return dataclasses.replace(
            cache, pools={k: zero(v) for k, v in cache.pools.items()})
    return dataclasses.replace(cache, k=zero(cache.k), v=zero(cache.v))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--no-guard", action="store_true")
    ap.add_argument("--policies", default="",
                    help="comma-separated per-tenant fence policies cycled "
                         "across tenants (e.g. 'modulo,check'); empty = "
                         "engine default (bitwise) for all")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    eng = ServeEngine(cfg, max_batch=8, max_len=256,
                      guard=not args.no_guard)
    pols = [FencePolicy(p.strip()) for p in args.policies.split(",")
            if p.strip()]
    per = max(eng._pool_slots() // max(args.tenants, 1) // 2, 2)
    for t in range(args.tenants):
        pol = pols[t % len(pols)] if pols else None
        eng.register_tenant(f"tenant{t}", per, policy=pol)
        if pol is not None:
            print(f"tenant{t}: policy={pol.value}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        tenant = f"tenant{i % args.tenants}"
        prompt = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
        eng.submit(tenant, prompt)
    t0 = time.time()
    out = eng.run(max_new_tokens=args.tokens)
    dt = time.time() - t0
    for rid, toks in sorted(out.items()):
        print(f"req {rid}: {toks[:8]}...")
    st = eng.manager.scheduler.stats
    print(f"{len(out)} requests, {args.tokens} tokens each, "
          f"{dt:.2f}s total, {eng.decode_steps} decode steps, "
          f"{int(st.total_launches)} scheduler launches")
    return out


if __name__ == "__main__":
    main()
