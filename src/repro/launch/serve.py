"""Multi-tenant serving engine — Guardian's spatial sharing applied to a
shared LM server, **unified with the GuardianManager launch path**.

One model, one KV pool, many mutually-untrusting tenants.  The pool's
sequence-slot space is carved into contiguous pow2 partitions by the
engine's :class:`~repro.core.manager.GuardianManager` (the same buddy
allocator, bounds table and quarantine lifecycle that fence raw kernel
launches).  The engine owns **no fence table and no row-assignment
policy of its own**:

* every prefill/decode step is registered as a *trusted kernel*
  (built in :mod:`repro.launch.steps`) and submitted as a
  :class:`~repro.core.scheduler.LaunchRequest`, enqueued and drained by
  the shared :class:`BatchedLaunchScheduler` — serving traffic and raw
  tenant launches ride one dispatch layer.  Steps **compile**: the
  manager's jitted trusted path runs each step as one device program
  (params/cache/guard are operands, never closure constants), with the
  eager path kept as a bit-identical ``--no-jit`` fallback;
* N engines may **share one manager** (``manager=`` / ``name=``): their
  tenants partition one global slot space, and the scheduler coalesces
  compatible steps from different engines into one fused device step —
  the multi-engine fused decode (:func:`serve_engines` drives the
  lockstep; generations stay bit-identical to solo runs);
* per-row fence params come from :meth:`GuardianManager.fence_table`
  (bitwise rows + the MODULO magic row table), gathered through a
  tenant-id column: batch row b belongs to tenant t(b), so the slot index
  of row b is fenced with t(b)'s bounds.  Even a corrupted scheduler or a
  forged slot id can only wrap inside the owning tenant's slots;
* batch-row selection uses the scheduler's shared
  :func:`~repro.core.scheduler.round_robin_interleave` fairness policy,
  weighted by the tenants' manager-side round-robin shares;
* tenants may carry **per-tenant fence policies** (a CHECK canary beside
  MODULO production tenants): the step gathers a per-row policy-code
  column and dispatches per element (``fence.apply_fence_mixed``);
* CHECK rows attribute: their ``ok`` predicates are collected per step
  and folded into the manager's ViolationLog, so a tenant spraying
  out-of-partition slot ids is quarantined by the same
  :class:`~repro.core.quarantine.QuarantineManager` poll that polices raw
  launches — and manager-side transitions propagate *back* into the
  engine through the quarantine subscription (pending requests dropped,
  pool slots scrubbed on eviction).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --tenants 3 --requests 6 --tokens 16 \
        --policies modulo,check
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.arena import PagePool
from repro.core.elastic import ElasticError, ResizeEvent
from repro.core.fence import FenceParams, FencePolicy
from repro.core.manager import GuardianManager
from repro.core.partition import OutOfArenaMemory
from repro.core.quarantine import QuarantinePolicy, TenantState
from repro.core.scheduler import round_robin_interleave
from repro.core.violations import NUM_KINDS, ViolationKind
from repro.launch.steps import (
    build_pool_relocation_step,
    build_trusted_serve_steps,
    join_cache_pool,
    split_cache_pool,
)
from repro.models import get_model
from repro.models import kvcache as KV
from repro.models.guard import GuardSpec

#: The engine's own manager tenant: owns the scratch partition where idle
#: batch rows park (their fenced writes must never land in a tenant's
#: slots) and is the tenant id under which step launches are enqueued.
#: Engines sharing a manager suffix it (``__scratch.e1``, ...) so each
#: engine gets its own scratch partition and launch queue.
ENGINE_TENANT = "__scratch"


@dataclasses.dataclass
class Request:
    tenant: str
    rid: int
    prompt: np.ndarray
    slot: int                      # absolute slot in the shared pool
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: paged mode: the request's *virtual* page ids (allocated at join
    #: time from the tenant's page extent, freed when the row leaves)
    pages: List[int] = dataclasses.field(default_factory=list)
    #: per-request generation budget (continuous driver; None = the
    #: driver-level ``max_new_tokens``)
    max_new: Optional[int] = None
    #: earliest drain cycle this request may join (arrival-trace replay)
    arrive: int = 0


@dataclasses.dataclass
class _RunState:
    """In-flight state of one engine's run: the lockstep driver
    (:func:`serve_engines`) enqueues one step per engine per drain."""

    rows: List[Request]
    slot_ids: np.ndarray
    meta: Any                      # cache meta (pool lives on the manager)
    guard: Optional[GuardSpec]
    batch: Optional[Dict]          # prefill inputs; None once prefilled
    remaining: int
    nxt: Optional[jax.Array] = None
    #: any CHECK-policy row in this run? (policies are fixed per tenant at
    #: registration, so per-step attribution can skip entirely otherwise)
    has_check: bool = False
    #: per-step next-token arrays, kept on device until _finalize — the
    #: decode loop never syncs, tokens materialize in one transfer
    trail: List[jax.Array] = dataclasses.field(default_factory=list)
    #: decode-step LaunchRequest signature, computed once per run — the
    #: operand structure (params/cache/guard trees) is invariant across a
    #: run's decode steps, so later requests skip the pytree flatten on
    #: the scheduler hot path
    decode_sig: Optional[tuple] = None


@dataclasses.dataclass
class _ContState:
    """One engine's in-flight state under the continuous driver
    (:func:`serve_continuous`): requests join and leave the fused step at
    drain-cycle boundaries, so a finished short request's row refills
    immediately instead of idling until the batch's longest request
    completes.  All sequence bookkeeping (page tables, seq lens, budgets)
    is host-authoritative — rebuilt into the meta operand every cycle —
    because a cycle may run *both* a prefill (joiners) and a decode
    (continuers) and the two steps' returned metas each advance every
    row."""

    rows: List[Optional[Request]]      # B entries; None = idle row
    left: np.ndarray                   # (B,) tokens still to emit
    lens: np.ndarray                   # (B,) host-authoritative seq lens
    nxt: jax.Array                     # (B,) device next-token operand
    #: per-cycle (tokens (B,) device array, row-owner rids) — tokens stay
    #: on device until _cont_finalize materializes the whole trail in one
    #: transfer
    trail: List[tuple] = dataclasses.field(default_factory=list)
    default_new: int = 16
    cycles: int = 0
    prefills: int = 0
    decodes: int = 0
    served: List[int] = dataclasses.field(default_factory=list)


def make_shared_manager(n_engines: int, max_batch: int = 8,
                        policy: FencePolicy = FencePolicy.BITWISE,
                        paged: bool = False, max_len: int = 256,
                        **kw) -> GuardianManager:
    """A GuardianManager sized so ``n_engines`` engines (each with its
    scratch partition plus up to one pool's worth of tenant slots) share
    one global slot space — the multi-engine fused-decode configuration.
    A guarded shared engine always fences, even while one tenant runs
    (``standalone_fast_path=False``), so generations are bit-identical
    solo vs shared.

    ``paged=True`` sizes the slot space in *virtual pages* instead of
    sequence slots (one slot per page — see ``ServeEngine(paged=True)``):
    co-hosted paged engines carve tenant page extents out of one global
    page space backing one shared physical page pool."""
    unit = max(max_len // KV.PAGE_SIZE, 1) if paged else 1
    return GuardianManager(
        total_slots=n_engines * 2 * _pow2(max_batch) * unit,
        policy=policy, standalone_fast_path=False, **kw)


class ServeEngine:
    """Continuous-batching (fixed-slot) multi-tenant server.

    A thin client of its :class:`GuardianManager`: request bookkeeping and
    operand marshalling live here; partitioning, fencing rows, launch
    scheduling, step compilation and quarantine all live on the manager
    side.  Pass ``manager=`` (see :func:`make_shared_manager`) to co-host
    several engines on one manager — their compatible steps fuse into one
    device step per lockstep drain (:func:`serve_engines`).

    Serve tenants may carry an SLO class (``register_tenant``'s
    ``tenant_class``): it governs elastic admission (compute watermark)
    and per-class reporting; the decode steps themselves run as trusted
    steps under the engine's scratch tenant, outside the raw-launch
    queue-age machinery.
    """

    def __init__(self, cfg, *, max_batch: int = 8, max_len: int = 256,
                 policy: FencePolicy = FencePolicy.BITWISE,
                 guard: bool = True, seed: int = 0,
                 quarantine_policy: Optional[QuarantinePolicy] = None,
                 manager: Optional[GuardianManager] = None,
                 name: Optional[str] = None,
                 jit_steps: bool = True,
                 telemetry: bool = True,
                 paged: bool = False,
                 max_inflight: Optional[int] = None,
                 temperature: float = 0.0,
                 top_k: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.guard_enabled = guard
        self.max_batch = max_batch
        self.max_len = max_len
        self.paged = paged
        self.max_inflight = min(max_inflight or max_batch, max_batch)
        self.temperature = temperature
        self.top_k = top_k
        if paged and cfg.family not in ("dense", "moe"):
            raise ValueError(
                "paged serve mode needs the global paged KV layout "
                f"(dense/moe transformers); {cfg.family!r} engines use "
                "the slab layout")
        #: paged mode: virtual pages per request (= max_len / page size)
        self.pages_per_req = max(max_len // KV.PAGE_SIZE, 1)
        slot_unit = self.pages_per_req if paged else 1
        self.params = self.api.init(jax.random.PRNGKey(seed))
        if manager is None:
            # pool = 2x the batch slots: the upper half is the engine's
            # scratch partition where idle batch rows park.
            # standalone_fast_path=False: a guarded engine always fences,
            # even with a single tenant (bit-identical solo vs shared).
            n_slots = 2 * _pow2(max_batch) * slot_unit
            self.manager = GuardianManager(
                total_slots=n_slots, policy=policy,
                standalone_fast_path=False,
                quarantine_policy=quarantine_policy,
                jit_trusted=jit_steps,
                telemetry=telemetry)
            # paged: parked rows only need ONE request's worth of scratch
            # page ids (they all resolve to the allocator-owned garbage
            # page); slab: half the pool is the per-row scratch slots
            scratch_slots = _pow2(self.pages_per_req) if paged \
                else n_slots // 2
            self.engine_tenant = ENGINE_TENANT
        else:
            # fencing, containment and step compilation are manager-wide
            # concerns: refuse per-engine overrides instead of silently
            # ignoring them (configure them on the shared manager)
            if (policy is not FencePolicy.BITWISE
                    or quarantine_policy is not None or not jit_steps
                    or not telemetry):
                raise ValueError(
                    "policy/quarantine_policy/jit_steps/telemetry are "
                    "owned by the shared GuardianManager; configure them "
                    "on the manager (see make_shared_manager) instead of "
                    "on a co-hosted ServeEngine")
            self.manager = manager
            n_slots = manager.bounds.total_slots
            scratch_slots = _pow2(self.pages_per_req) if paged \
                else _pow2(max_batch)
            policy = manager.policy
            if name is None:
                name = "e%d" % sum(
                    1 for t in manager.bounds.tenants()
                    if t.startswith(ENGINE_TENANT))
            self.engine_tenant = f"{ENGINE_TENANT}.{name}"
        self.policy = policy
        # ONE pool for the manager's full slot space: the pool tensors are
        # adopted by the manager as a PoolArena (the manager is the only
        # entity with device access), so co-hosted engines of the same
        # model shape share one KV pool — globally-partitioned slot ids
        # address it directly through the shared fence table, and the
        # per-engine footprint does not grow with the engine count.
        if paged:
            # Geometry-only pool key: the global page pool is one
            # (L, P, page, KH, D) tensor — engines serving *different*
            # model shapes with the same KV geometry share it (and the
            # manager drain), keeping model identity in the step key so
            # their step symbols stay distinct.
            self._n_phys = _pow2(n_slots)
            pool_key = (f"paged:L{cfg.decoder_layers}:kh{cfg.n_kv_heads}:"
                        f"d{cfg.head_dim}:pg{KV.PAGE_SIZE}:"
                        f"P{self._n_phys}:f32")
            step_key = (f"{cfg.name}:{cfg.family}:{cfg.n_layers}x"
                        f"{cfg.d_model}v{cfg.vocab}:{pool_key}")
        else:
            pool_key = (f"{cfg.name}:{cfg.family}:{cfg.n_layers}x"
                        f"{cfg.d_model}v{cfg.vocab}:s{n_slots}:l{max_len}")
            step_key = None
        self._steps = build_trusted_serve_steps(
            self.api, pool_key, step_key=step_key,
            temperature=temperature, top_k=top_k)
        # A later co-hosted engine adopts the already-registered pool:
        # build its cache with a single-slot pool instead (the meta half —
        # slot ids, seq lens, page tables — is slot-count independent), so
        # the dominant allocation happens once per pool, not once per
        # engine.
        adopted = self._steps.pool_name in self.manager.arenas
        if paged:
            cache = KV.init_global_kv_cache(
                cfg, max_batch, max_len, 1 if adopted else self._n_phys,
                dtype=jnp.float32)
        elif cfg.family == "ssm":
            cache = self.api.init_cache(max_batch,
                                        slots=1 if adopted else n_slots)
        else:
            cache = self.api.init_cache(max_batch, max_len,
                                        dtype=jnp.float32,
                                        slots=1 if adopted else n_slots)
        pool, self._meta = split_cache_pool(cache)
        self._client = self.manager.register_tenant(self.engine_tenant,
                                                    scratch_slots)
        self._scratch = self.manager.bounds.lookup(self.engine_tenant)
        #: tenants served through THIS engine (registered or submitted
        #: here) — scopes the eviction-time pool scrub to the engine that
        #: owns the evicted tenant's rows
        self._tenants: set = set()
        self.manager.quarantine.subscribe(self._on_transition)
        # elastic resizes propagate into the serving plane: a tenant's
        # (or this engine's scratch) extent moving means its KV pool
        # slots move with it and its pending requests re-address
        self.manager.elastic.subscribe(self._on_resize)
        # idempotent: a co-hosted engine adopts the existing pool (its
        # single-slot throwaway tensors are dropped before any write)
        self._pool = self._steps.register(self.manager, pool)
        if paged and self._pool.pages is None:
            # one virt->phys allocator per pool arena, shared by every
            # co-hosted engine; virt space = the manager's page-granular
            # slot space, phys space = the pool tensor's page axis
            self._pool.pages = PagePool(self._n_phys, n_slots)
        self.rejected: List[int] = []     # rids dropped by quarantine
        self._requests: List[Request] = []
        #: rid -> RequestSpan handle (absent entirely when telemetry is
        #: off — every ledger call is None-tolerant)
        self._spans: Dict[int, Any] = {}
        self._rid = 0
        self.decode_steps = 0
        #: sampled decode steps thread a fresh PRNG key per cycle
        self._sample_key = jax.random.PRNGKey(seed ^ 0x5EED) \
            if temperature > 0 else None
        # evictions fired *during* run() must survive the run-end cache
        # commit: the committed cache is re-scrubbed from this list
        self._in_run = False
        self._pending_scrubs: List[tuple] = []

    @property
    def cache(self):
        """The engine's full cache view: the manager-owned shared pool
        joined with this engine's per-batch meta.  Assignment splits the
        value back (pool half commits to the shared arena — visible to
        every co-hosted engine)."""
        return join_cache_pool(self._pool.buf, self._meta)

    @cache.setter
    def cache(self, value):
        self._pool.buf, self._meta = split_cache_pool(value)

    def _pool_slots(self) -> int:
        c = self.cache
        if hasattr(c, "k"):
            return c.k.shape[1]
        if hasattr(c, "pools"):
            return next(iter(c.pools.values())).shape[1]
        return c.kv.k.shape[1]

    # ------------------------------------------------------------------ #
    # Tenant lifecycle (all state on the manager)                        #
    # ------------------------------------------------------------------ #
    @property
    def bounds(self):
        return self.manager.bounds

    @property
    def quarantine(self):
        """The shared lifecycle driver (manager-owned)."""
        return self.manager.quarantine

    def register_tenant(self, name: str, slots: int,
                        policy: Optional[FencePolicy] = None,
                        weight: int = 1,
                        tenant_class=None):
        """Carve a pool partition for ``name``; returns the Partition.

        ``policy`` optionally overrides the engine default for this
        tenant's rows (per-row mixed fencing); ``weight`` is the tenant's
        weighted-round-robin share of batch rows; ``tenant_class`` is any
        ``GuardianManager.register_tenant`` class spec (a
        TenantClassPolicy, a TenantClass, or ``"latency_critical"`` /
        ``"best_effort"``) attaching an SLO class to the tenant.  Note
        the engine's own *launches* ride under its scratch tenant, so a
        serve tenant's class governs admission and reporting; queue-age
        SLO enforcement applies to raw-launch tenants."""
        self.manager.register_tenant(name, slots, policy=policy,
                                     weight=weight,
                                     tenant_class=tenant_class)
        self._tenants.add(name)
        part = self.manager.bounds.lookup(name)
        if self.paged:
            # the tenant's partition is a *virtual page* extent: back it
            # with physical pages now (hand-over is all-or-nothing) and
            # tell the elastic plane resizes need no copy step
            self._pool.pages.bind_extent(name, part.base, part.size)
            self.manager.elastic.mark_virtual(name)
        return part

    def quarantine_tenant(self, name: str, reason: str = "") -> List[int]:
        """Reject the tenant via the manager's quarantine (the subscription
        drops its pending requests; new submissions raise).  Returns the
        dropped request ids."""
        before = len(self.rejected)
        self.manager.quarantine.quarantine(name, reason=reason)
        return self.rejected[before:]

    def evict_tenant(self, name: str) -> None:
        """Scrub the tenant's pool slots and return its partition to the
        buddy allocator (manager-side reclamation; the subscription scrubs
        the serve pool while the bounds are still resolvable)."""
        self.manager.quarantine.evict(name)

    def readmit_tenant(self, name: str) -> None:
        self.manager.quarantine.readmit(name)

    def _on_resize(self, ev: ResizeEvent) -> None:
        """Elastic extent changes propagate into the serving plane: when
        a tenant served here (or this engine's scratch partition) moves,
        its KV/state pool slots move with it — a pool relocation step
        dispatched through the same trusted scheduler path as the
        prefill/decode steps — and its pending requests re-address.
        In-place grows change no addresses, so only the bookkeeping
        refreshes.  Data-moving resizes only fire while the engine is
        idle (the elastic manager holds during serve runs), so no staged
        guard or slot-id operand can go stale."""
        mine = ev.tenant_id in self._tenants \
            or ev.tenant_id == self.engine_tenant
        if not mine:
            return
        if self.paged:
            # zero-copy resize: the extent is virtual pages — rewrite the
            # PagePool map (bytes stay in their physical pages) and the
            # in-flight requests' virtual ids; no relocation step exists
            pages = self._pool.pages
            if ev.tenant_id != self.engine_tenant:
                if ev.moved:
                    pages.rebase_extent(ev.tenant_id, ev.new_base)
                    delta = ev.new_base - ev.old_base
                    for r in self._requests:
                        if r.tenant == ev.tenant_id and not r.done \
                                and r.pages:
                            r.pages = [p + delta for p in r.pages]
                if ev.new_size > ev.old_size:
                    pages.bind_extent(ev.tenant_id, ev.new_base,
                                      ev.new_size)
                elif ev.new_size < ev.old_size:
                    pages.shrink_extent(ev.tenant_id, ev.new_size)
            else:
                self._scratch = self.manager.bounds.lookup(
                    self.engine_tenant)
            return
        if ev.moved:
            size = min(ev.old_size, ev.new_size)
            name = (f"elastic.pool[{self._steps.pool_name}]:"
                    f"{ev.old_base}->{ev.new_base}x{size}")
            self.manager.elastic.dispatch_relocation(
                ev.tenant_id, name,
                build_pool_relocation_step(ev.old_base, ev.new_base, size),
                pool_arena=self._steps.pool_name)
            for r in self._requests:
                if r.tenant == ev.tenant_id and not r.done:
                    r.slot = ev.new_base + (r.slot - ev.old_base)
        if ev.tenant_id == self.engine_tenant:
            self._scratch = self.manager.bounds.lookup(self.engine_tenant)

    def _on_transition(self, tenant_id: str, state: TenantState) -> None:
        """Manager-side quarantine events propagate into the serving plane
        (including transitions the engine never initiated, e.g. a
        ViolationLog threshold crossing from raw-launch traffic)."""
        if tenant_id.startswith(ENGINE_TENANT):
            return
        if state is TenantState.EVICTED and tenant_id in self._tenants:
            # fires before partition reclamation: bounds still resolvable.
            # Scoped to the owning engine: only the engine that served the
            # tenant ever wrote its slots, and with a shared pool the
            # co-hosted engines' subscriptions would otherwise each repeat
            # the same whole-pool scrub.  This zeroing is the KV-leak
            # barrier — the reclaimed slots must hand over empty.
            part = self.manager.bounds.lookup(tenant_id)
            if self.paged:
                # translate the virtual extent to its physical pages
                # BEFORE releasing them (the map rows zero on release),
                # then zero those pages — the KV-leak barrier for the
                # global pool
                pages = self._pool.pages
                pm = pages.page_map
                phys = tuple(int(pm[v]) for v in
                             range(part.base, part.base + part.size)
                             if int(pm[v]))
                pages.release_extent(tenant_id)
                if self._in_run:
                    self._pending_scrubs.append(("phys", phys))
                else:
                    self.cache = _scrub_phys_pages(self.cache, phys)
            elif self._in_run:
                # run() holds a newer local cache that overwrites
                # self.cache at run-end (and, under donation, may have
                # consumed these very buffers) — scrub the committed
                # cache at run-end instead
                self._pending_scrubs.append((part.base, part.size))
            else:
                self.cache = _scrub_slots(self.cache, part.base, part.size)
        if not state.admissible:
            dropped = [r.rid for r in self._requests
                       if r.tenant == tenant_id and not r.done]
            self._requests = [r for r in self._requests
                              if r.done or r.tenant != tenant_id]
            self.rejected.extend(dropped)
            tel = self.manager.telemetry
            for rid in dropped:
                tel.spans.close(self._spans.pop(rid, None), "evicted")

    def submit(self, tenant: str, prompt: np.ndarray,
               max_new: Optional[int] = None, arrive: int = 0) -> int:
        """Queue one generation request; returns the request id keyed in
        :meth:`run`'s result dict.  Raises if the tenant is quarantined.
        Claims a KV slot from the tenant's pool partition, growing it
        through the elastic control plane when hard-full.

        ``max_new`` (continuous driver) caps this request's generation
        below the driver-wide budget; ``arrive`` is the earliest drain
        cycle the request may join the batch (arrival-trace replay).  In
        paged mode no slot is claimed here — virtual pages are allocated
        when the request joins a batch row, so a queued request costs
        nothing until it runs."""
        self.manager.quarantine.check_admission(tenant, "submit")
        part = self.manager.bounds.lookup(tenant)
        # a manager-registered tenant becomes this engine's to serve (and
        # therefore to scrub on eviction) the moment it submits here
        self._tenants.add(tenant)
        if self.paged:
            rid = self._rid
            self._rid += 1
            self._requests.append(Request(
                tenant=tenant, rid=rid, prompt=np.asarray(prompt),
                slot=-1, max_new=max_new, arrive=arrive))
            tel = self.manager.telemetry
            if tel.enabled:
                tel.registry.inc("requests", tenant=tenant)
                # a future-arrival request defers its span clock: queue
                # time the trace replay asked for is not queue time the
                # system imposed (_cont_join begins it at eligibility)
                self._open_span(tenant, rid, defer=arrive > 0)
            return rid
        used = {r.slot for r in self._requests if not r.done
                and r.tenant == tenant}
        free = [s for s in range(part.base, part.end) if s not in used]
        if not free:
            # the pool partition is hard full: grow it through the
            # elastic control plane (KV pools resize with their tenant —
            # the listener moves the slots if the extent relocates) and
            # retry once
            try:
                part = self.manager.elastic.grow(tenant)
            except (ElasticError, OutOfArenaMemory):
                raise RuntimeError(f"tenant {tenant}: no free slots")
            used = {r.slot for r in self._requests if not r.done
                    and r.tenant == tenant}
            free = [s for s in range(part.base, part.end)
                    if s not in used]
            if not free:
                raise RuntimeError(f"tenant {tenant}: no free slots")
        rid = self._rid
        self._rid += 1
        self._requests.append(Request(tenant=tenant, rid=rid,
                                      prompt=np.asarray(prompt),
                                      slot=free[0]))
        tel = self.manager.telemetry
        if tel.enabled:
            tel.registry.inc("requests", tenant=tenant)
            self._open_span(tenant, rid)
        # occupancy report: the pressure tracker sees serve tenants too
        # (non-shrinkable — the engine owns slot placement)
        self.manager.elastic.pressure.observe(
            tenant, len(used) + 1, part.size)
        return rid

    def _open_span(self, tenant: str, rid: int,
                   defer: bool = False) -> None:
        """Open the request's span on the manager's ledger.  An SLO class
        on the tenant attaches its slack budget (latency-critical only —
        best-effort spans complete unbudgeted)."""
        tel = self.manager.telemetry
        if not tel.enabled:
            return
        cp = self.manager.class_policy_of(tenant)
        cls = cp.tenant_class.value if cp is not None else None
        budget = cp.queue_age_budget \
            if cp is not None and cp.is_latency_critical else None
        self._spans[rid] = tel.spans.open(tenant, rid, cls=cls,
                                          budget=budget, defer=defer)

    def withdraw(self, rid: int) -> bool:
        """Remove a queued (never-ran) request; returns True when
        withdrawn.  Refuses requests that are done, hold pool pages, or
        while a run is in flight — withdrawal is a queue operation, not a
        cancellation of device work."""
        for r in self._requests:
            if r.rid != rid:
                continue
            if r.done or r.pages or self._in_run:
                return False
            self._requests.remove(r)
            self.manager.telemetry.spans.close(
                self._spans.pop(rid, None), "withdrawn")
            return True
        return False

    # ------------------------------------------------------------------ #
    def _guard_for_rows(self, rows: List[Optional[Request]]
                        ) -> Optional[GuardSpec]:
        if not self.guard_enabled:
            return None
        table, row_of = self.manager.fence_table()
        # tenant-id column: batch row b -> fence-table row of its tenant
        # (idle rows park in the engine's scratch partition)
        cols = np.full((self.max_batch,), row_of[self.engine_tenant],
                       np.int32)
        pol = np.full((self.max_batch,), self.policy.code, np.int32)
        for i, r in enumerate(rows):
            if r is not None:
                cols[i] = row_of[r.tenant]
                pol[i] = self.manager.policy_of(r.tenant).code
        slot_params = table.gather(jnp.asarray(cols))
        # row-mixed policies only when some tenant actually diverges from
        # the engine default (the homogeneous path stays bit-identical)
        mixed = bool((pol != self.policy.code).any())
        row_policy = jnp.asarray(pol) if mixed else None
        if self.paged:
            # kv space = per-row *virtual page* extents (the fence-table
            # rows ARE page extents in paged mode); virt->phys goes
            # through the manager-owned map, then the "page" clamp keeps
            # even a corrupted map entry inside the pool tensor
            return GuardSpec(
                policy=self.policy,
                vocab=FenceParams(base=0, size=_pow2(self.cfg.vocab)),
                kv=slot_params,
                expert=(FenceParams(base=0, size=_pow2(
                    self.cfg.moe.num_experts)) if self.cfg.moe else None),
                page=FenceParams(base=0, size=self._n_phys),
                row_policy=row_policy,
                page_map=jnp.asarray(self._pool.pages.page_map),
            )
        pages = self.cache.kv.pages_per_slot if hasattr(self.cache, "kv") \
            else (self.cache.pages_per_slot if hasattr(self.cache, "k")
                  else 1)
        return GuardSpec(
            policy=self.policy,
            vocab=FenceParams(base=0, size=_pow2(self.cfg.vocab)),
            kv=slot_params,
            state=slot_params,
            expert=(FenceParams(base=0, size=_pow2(
                self.cfg.moe.num_experts)) if self.cfg.moe else None),
            page=FenceParams(base=0, size=_pow2(max(pages, 1))),
            row_policy=row_policy,
        )

    def _select_rows(self) -> List[Request]:
        """Batch-row assignment through the scheduler's shared weighted
        round-robin fairness policy (§4.2.4).  Quarantined tenants'
        requests never occupy a row — their slots re-route to admissible
        co-tenants."""
        by_tenant: Dict[str, List[Request]] = {}
        for r in self._requests:
            if r.done:
                continue
            state = self.manager.quarantine.state_of(r.tenant)
            if state is None or state.admissible:
                by_tenant.setdefault(r.tenant, []).append(r)
        weights = {t: self.manager.weight_of(t) for t in by_tenant}
        return round_robin_interleave(by_tenant, self.max_batch,
                                      weights=weights)

    def _attribute(self, rows: List[Request],
                   slot_ids: np.ndarray) -> None:
        """Per-step CHECK attribution for the serving plane: a CHECK row
        whose slot id left its owner's partition is a detected violation.

        Computed host-side from the same bounds the in-step fence used
        (the clamp happens on device; detection must not depend on model
        internals — slot fences run inside scan-over-layers).  One GATHER
        count per offending row per step, folded into the manager's
        ViolationLog so serve traffic feeds the same QuarantineManager
        poll as raw launches."""
        if not self.guard_enabled:
            return
        for i, r in enumerate(rows):
            state = self.manager.quarantine.state_of(r.tenant)
            if state is not None and not state.admissible:
                # quarantined/evicted mid-run: the row is a lame duck —
                # its bounds/log row may already be reclaimed
                continue
            if self.manager.policy_of(r.tenant) is not FencePolicy.CHECK:
                continue
            part = self.manager.bounds.lookup(r.tenant)
            if not (part.base <= int(slot_ids[i]) < part.end):
                counts = np.zeros((NUM_KINDS,), np.int32)
                counts[int(ViolationKind.GATHER)] = 1
                self.manager.violog.add(r.tenant, counts)

    # ------------------------------------------------------------------ #
    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Prefill all pending, then decode until done/limit.  Every step
        is a LaunchRequest drained by the manager's scheduler.  Engines
        sharing a manager should run through :func:`serve_engines`
        (slab/lockstep) or :func:`serve_continuous` (paged) instead, so
        their steps share drains."""
        if self.paged:
            return serve_continuous([self],
                                    max_new_tokens=max_new_tokens)[0]
        return serve_engines([self], max_new_tokens=max_new_tokens)[0]

    # -- lockstep phases (driven by serve_engines) --------------------- #
    def _begin(self, max_new_tokens: int) -> Optional[_RunState]:
        rows = self._select_rows()
        if not rows:
            return None
        self._in_run = True
        tel = self.manager.telemetry
        if tel.enabled:
            # requests the wave left behind (batch full) are *held* for
            # the whole run — _finalize reverts survivors to "queue"
            picked = {r.rid for r in rows}
            for r in self._requests:
                if not r.done and r.rid not in picked:
                    tel.spans.phase(self._spans.get(r.rid), "hold")
        B = self.max_batch
        plen = max(len(r.prompt) for r in rows)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(rows):
            toks[i, :len(r.prompt)] = r.prompt
        slot_ids = np.full((B,), self._scratch.base, np.int32)
        for i, r in enumerate(rows):
            slot_ids[i] = r.slot
        meta = _reset_seq_lens(self._meta_with_slots(jnp.asarray(slot_ids)))
        guard = self._guard_for_rows(rows + [None] * (B - len(rows)))

        if self.cfg.family == "encdec":
            batch = {"src": jnp.zeros(
                (B, 16, self.cfg.d_model), jnp.float32),
                "tgt": jnp.asarray(toks)}
        else:
            batch = {"tokens": jnp.asarray(toks)}
        has_check = any(
            self.manager.policy_of(r.tenant) is FencePolicy.CHECK
            for r in rows)
        return _RunState(rows=rows, slot_ids=slot_ids, meta=meta,
                         guard=guard, batch=batch,
                         remaining=max_new_tokens, has_check=has_check)

    def _enqueue_step(self, st: _RunState):
        """Attribute CHECK rows, then enqueue this engine's next step as a
        LaunchRequest (the request doubles as the result handle).  The
        manager drain — shared with every co-hosted engine — happens in
        :func:`serve_engines`."""
        if st.has_check:
            self._attribute(st.rows, st.slot_ids)
        tel = self.manager.telemetry
        if tel.enabled:
            name = "prefill" if st.batch is not None else "decode"
            for r in st.rows:
                tel.spans.phase(self._spans.get(r.rid), name)
        if st.batch is not None:       # prefill
            return self._client.launch_kernel(
                self._steps.prefill_name,
                args=(self.params, st.meta, st.batch, st.guard))
        st.trail.append(st.nxt)        # stays on device until _finalize
        req = self._client.launch_kernel(
            self._steps.decode_name,
            args=(self.params, st.meta, st.nxt, st.guard))
        if st.decode_sig is None:
            st.decode_sig = req.signature
        else:
            req._sig = st.decode_sig
        return req

    def _finish_step(self, st: _RunState, req) -> bool:
        """Consume the drained step's result; True while decodes remain.
        The step sampled on device — no logits, no host sync here (the
        pool half of the cache was committed by the manager)."""
        st.meta, st.nxt = req.result
        if st.batch is not None:
            st.batch = None            # prefilled; decodes follow
            return st.remaining > 0
        self.decode_steps += 1
        st.remaining -= 1
        return st.remaining > 0

    def _finalize(self, st: _RunState) -> Dict[int, List[int]]:
        self._meta = st.meta           # pool already lives on the manager
        # one transfer materializes every step's sampled tokens
        if st.trail:
            toks = np.asarray(jnp.stack(st.trail))       # (steps, B)
            for i, r in enumerate(st.rows):
                r.generated.extend(int(t) for t in toks[:, i])
        # a mid-run eviction was deferred to here: re-apply to the cache
        # we just committed (zeroing is idempotent, nothing re-registers
        # inside a single-threaded run)
        self._apply_pending_scrubs()
        # rows whose tenant was quarantined/evicted mid-run were already
        # dropped + recorded in self.rejected: they must not also be
        # reported as served (their clamped generations are discarded)
        out: Dict[int, List[int]] = {}
        tel = self.manager.telemetry
        for r in st.rows:
            state = self.manager.quarantine.state_of(r.tenant)
            if state is None or state.admissible:
                r.done = True
                out[r.rid] = r.generated
                tel.spans.close(self._spans.pop(r.rid, None), "complete")
        if tel.enabled:
            # survivors the wave held now re-queue for the next run
            for r in self._requests:
                if not r.done:
                    tel.spans.phase(self._spans.get(r.rid), "queue")
        return out

    def _apply_pending_scrubs(self) -> None:
        for item in self._pending_scrubs:
            if item and item[0] == "phys":
                self.cache = _scrub_phys_pages(self.cache, item[1])
            else:
                self.cache = _scrub_slots(self.cache, *item)
        self._pending_scrubs.clear()

    def _meta_with_slots(self, slot_ids):
        c = self._meta
        if hasattr(c, "slot_ids"):
            return dataclasses.replace(c, slot_ids=slot_ids)
        if hasattr(c, "kv"):   # hybrid / encdec
            kv = dataclasses.replace(c.kv, slot_ids=slot_ids)
            if hasattr(c, "state"):
                st = dataclasses.replace(c.state, slot_ids=slot_ids)
                return dataclasses.replace(c, kv=kv, state=st)
            return dataclasses.replace(c, kv=kv)
        return c

    # -- continuous batching (paged mode; serve_continuous drives) ----- #
    def _admissible(self, tenant: str) -> bool:
        state = self.manager.quarantine.state_of(tenant)
        return state is None or state.admissible

    def _used_pages(self, tenant: str) -> set:
        used: set = set()
        for r in self._requests:
            if r.tenant == tenant and not r.done:
                used.update(r.pages)
        return used

    def _alloc_pages(self, tenant: str) -> Optional[List[int]]:
        """``pages_per_req`` free virtual ids from the tenant's extent,
        growing through the elastic plane once when full (in paged mode a
        grow — even a relocating one — is host bookkeeping only, so it is
        safe at any drain-cycle boundary)."""
        part = self.manager.bounds.lookup(tenant)
        used = self._used_pages(tenant)
        free = [v for v in range(part.base, part.end) if v not in used]
        if len(free) < self.pages_per_req:
            try:
                part = self.manager.elastic.grow(tenant)
            except (ElasticError, OutOfArenaMemory):
                return None
            used = self._used_pages(tenant)
            free = [v for v in range(part.base, part.end)
                    if v not in used]
            if len(free) < self.pages_per_req:
                return None
        return free[:self.pages_per_req]

    def _cont_begin(self, max_new_tokens: int) -> _ContState:
        self._in_run = True
        B = self.max_batch
        return _ContState(rows=[None] * B,
                          left=np.zeros((B,), np.int64),
                          lens=np.zeros((B,), np.int64),
                          nxt=jnp.zeros((B,), jnp.int32),
                          default_new=max_new_tokens)

    def _cont_leave(self, st: _ContState) -> None:
        """Cycle boundary: rows whose request exhausted its budget (or
        whose tenant lost admissibility) leave — their virtual pages
        return to the tenant's free pool immediately."""
        tel = self.manager.telemetry
        for i, r in enumerate(st.rows):
            if r is None:
                continue
            if not self._admissible(r.tenant):
                r.pages = []
                st.rows[i] = None
                # span already closed by _on_transition; idempotent
                tel.spans.close(self._spans.pop(r.rid, None), "evicted")
                continue
            if st.left[i] <= 0:
                r.pages = []
                r.done = True
                st.served.append(r.rid)
                st.rows[i] = None
                tel.spans.close(self._spans.pop(r.rid, None), "complete")

    def _cont_join(self, st: _ContState) -> List[int]:
        """Refill idle rows from the admission queue (FIFO, gated on the
        request's arrival cycle and page availability).  Pages are
        allocated here — a queued request costs nothing until it joins.
        Returns the joined row indices (this cycle's prefill set)."""
        active = sum(1 for r in st.rows if r is not None)
        waiting = [r for r in self._requests
                   if not r.done and not r.pages
                   and r.arrive <= st.cycles
                   and self._admissible(r.tenant)]
        tel = self.manager.telemetry
        if tel.enabled:
            # deferred spans (future-arrival submits) start their clock
            # the cycle the request becomes eligible for admission
            for r in waiting:
                tel.spans.begin(self._spans.get(r.rid))
        # latency-critical requests admit ahead of class-less /
        # best-effort peers (stable: FIFO within a class — and a no-op
        # ordering when no tenant carries a class)
        def _lc_rank(req: Request) -> int:
            cp = self.manager.class_policy_of(req.tenant)
            return 0 if cp is not None and cp.is_latency_critical else 1
        waiting.sort(key=_lc_rank)
        joiners: List[int] = []
        stalled_rids: set = set()
        wi = 0
        for i in range(self.max_batch):
            if st.rows[i] is not None or active >= self.max_inflight:
                continue
            while wi < len(waiting):
                r = waiting[wi]
                wi += 1
                pages = self._alloc_pages(r.tenant)
                if pages is None:
                    # tenant page-full: later arrivals may fit
                    stalled_rids.add(r.rid)
                    continue
                r.pages = pages
                st.rows[i] = r
                st.left[i] = r.max_new if r.max_new is not None \
                    else st.default_new
                st.lens[i] = 0
                joiners.append(i)
                active += 1
                break
        if tel.enabled and waiting:
            # attribute this cycle's wait for the left-behind requests:
            # page-pool stall > bypassed-by-LC preempt > capacity hold >
            # plain queueing
            lc_joined = any(_lc_rank(st.rows[i]) == 0 for i in joiners)
            full = active >= self.max_inflight \
                or all(row is not None for row in st.rows)
            for r in waiting:
                if r.pages:
                    continue           # joined this cycle
                sp = self._spans.get(r.rid)
                if r.rid in stalled_rids:
                    tel.spans.phase(sp, "stall")
                elif lc_joined and _lc_rank(r) == 1:
                    tel.spans.phase(sp, "preempt")
                elif full:
                    tel.spans.phase(sp, "hold")
                else:
                    tel.spans.phase(sp, "queue")
        # allocator invariant: active requests never share a page, and
        # every page stays inside its owner's virtual extent (cheap host
        # ints — this is the join/leave-churn aliasing check)
        seen: Dict[int, str] = {}
        for r in st.rows:
            if r is None:
                continue
            part = self.manager.bounds.lookup(r.tenant)
            for p in r.pages:
                assert part.base <= p < part.end, \
                    f"page {p} outside {r.tenant} extent"
                assert p not in seen, \
                    f"page {p} aliased: {seen[p]} vs {r.tenant}"
                seen[p] = r.tenant
        return joiners

    def _cont_meta(self, st: _ContState, active: set):
        """Host-authoritative meta for one step: rows in ``active`` carry
        their real page table + seq len; every other row parks on the
        engine's scratch page ids (which the PagePool maps to the
        allocator-owned garbage page — parked writes land nowhere)."""
        B, P = self.max_batch, self.pages_per_req
        scratch = [self._scratch.base + (j % self._scratch.size)
                   for j in range(P)]
        pt = np.empty((B, P), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(st.rows):
            if r is not None and i in active:
                pt[i] = r.pages
                lens[i] = int(st.lens[i])
            else:
                pt[i] = scratch
        return dataclasses.replace(
            self._meta, page_table=jnp.asarray(pt),
            slot_ids=jnp.zeros((B,), jnp.int32),
            seq_lens=jnp.asarray(lens))

    def _cont_dispatch(self, st: _ContState, joiners: List[int]):
        """Enqueue this cycle's steps: one prefill covering the joiner
        rows and one decode covering the continuing rows (each step parks
        the other set on scratch pages, so their pool writes are
        disjoint and dispatch order is irrelevant).  Returns the result
        handles + row sets for :meth:`_cont_finish`."""
        continuers = [i for i, r in enumerate(st.rows)
                      if r is not None and i not in set(joiners)]
        tel = self.manager.telemetry
        if tel.enabled:
            for i in joiners:
                tel.spans.phase(self._spans.get(st.rows[i].rid),
                                "prefill")
            for i in continuers:
                tel.spans.phase(self._spans.get(st.rows[i].rid),
                                "decode")
        pre_req = dec_req = None
        plen = 0
        if joiners:
            # per-step guard: rows parked for THIS step fence to the
            # engine's scratch extent (whose virtual ids resolve to the
            # garbage page) — fencing them with their tenant's extent
            # would wrap the scratch ids INTO the tenant's live pages
            guard = self._guard_for_rows(
                [r if i in set(joiners) else None
                 for i, r in enumerate(st.rows)])
            plen = max(len(st.rows[i].prompt) for i in joiners)
            toks = np.zeros((self.max_batch, plen), np.int32)
            for i in joiners:
                toks[i, :len(st.rows[i].prompt)] = st.rows[i].prompt
            pre_req = self._client.launch_kernel(
                self._steps.prefill_name,
                args=(self.params, self._cont_meta(st, set(joiners)),
                      {"tokens": jnp.asarray(toks)}, guard))
            st.prefills += 1
        if continuers:
            guard = self._guard_for_rows(
                [r if i in set(continuers) else None
                 for i, r in enumerate(st.rows)])
            meta = self._cont_meta(st, set(continuers))
            if self._sample_key is not None:
                self._sample_key, sub = jax.random.split(self._sample_key)
                x = (st.nxt, sub)
            else:
                x = st.nxt
            dec_req = self._client.launch_kernel(
                self._steps.decode_name,
                args=(self.params, meta, x, guard))
            st.decodes += 1
            self.decode_steps += 1
        return pre_req, dec_req, joiners, continuers, plen

    def _cont_finish(self, st: _ContState, pre_req, dec_req,
                     joiners: List[int], continuers: List[int],
                     plen: int) -> None:
        """Merge the cycle's results on device (no host sync), record the
        emitted tokens, advance budgets/lens."""
        if pre_req is not None and dec_req is not None:
            _, pre_nxt = pre_req.result
            _, dec_nxt = dec_req.result
            mask = np.zeros((self.max_batch,), bool)
            mask[joiners] = True
            nxt = jnp.where(jnp.asarray(mask), pre_nxt, dec_nxt)
        elif pre_req is not None:
            _, nxt = pre_req.result
        elif dec_req is not None:
            _, nxt = dec_req.result
        else:
            st.cycles += 1
            return
        emitting = set(joiners) | set(continuers)
        owners = tuple(
            st.rows[i].rid if i in emitting and st.rows[i] is not None
            else None for i in range(self.max_batch))
        st.trail.append((nxt, owners))
        st.nxt = nxt
        for i in joiners:
            # the device wrote the *padded* wave length (lockstep
            # semantics: pad tokens are cached and attended)
            st.lens[i] = plen
            st.left[i] -= 1
        for i in continuers:
            st.lens[i] += 1
            st.left[i] -= 1
        st.cycles += 1

    def _cont_waiting(self, st: _ContState) -> bool:
        return any(not r.done and not r.pages
                   and self._admissible(r.tenant)
                   for r in self._requests)

    def _cont_gauges(self, st: _ContState) -> None:
        tel = self.manager.telemetry
        if not tel.enabled:
            return
        inflight: Dict[str, int] = {}
        for r in st.rows:
            if r is not None:
                inflight[r.tenant] = inflight.get(r.tenant, 0) + 1
        for t in self._tenants:
            tel.registry.set_gauge("serve_inflight",
                                   float(inflight.get(t, 0)), tenant=t)
            try:
                part = self.manager.bounds.lookup(t)
            except Exception:
                continue
            tel.registry.set_gauge(
                "page_occupancy",
                len(self._used_pages(t)) / max(part.size, 1), tenant=t)
        pages = self._pool.pages
        if pages is not None:
            tel.registry.set_gauge("pool_page_occupancy",
                                   pages.occupancy())

    def _cont_finalize(self, st: _ContState) -> Dict[int, List[int]]:
        self._in_run = False
        self._apply_pending_scrubs()
        if st.trail:
            # one transfer materializes every cycle's emitted tokens
            toks = np.asarray(jnp.stack([t for t, _ in st.trail]))
            by_rid = {r.rid: r for r in self._requests}
            for c, (_, owners) in enumerate(st.trail):
                for i, rid in enumerate(owners):
                    if rid is not None and rid in by_rid:
                        by_rid[rid].generated.append(int(toks[c, i]))
        by_rid = {r.rid: r for r in self._requests}
        return {rid: by_rid[rid].generated for rid in st.served
                if rid in by_rid}


def serve_engines(engines: List[ServeEngine], max_new_tokens: int = 16
                  ) -> List[Dict[int, List[int]]]:
    """Lockstep driver for engines sharing one GuardianManager: every
    active engine enqueues its next prefill/decode step, then ONE manager
    drain dispatches them — compatible steps (same model shape, same
    phase) fuse into a single compiled device step, so N engines cost one
    dispatch per lockstep instead of N.  Returns one ``rid -> tokens``
    dict per engine, in order.  A single-engine call is exactly
    ``engine.run()``."""
    if not engines:
        return []
    mgr = engines[0].manager
    if any(e.manager is not mgr for e in engines[1:]):
        raise ValueError("serve_engines needs engines sharing one "
                         "GuardianManager (see make_shared_manager)")
    if any(e.paged for e in engines):
        raise ValueError("paged engines batch per-request — drive them "
                         "with serve_continuous")
    # elastic resizes that move data defer for the whole run: the staged
    # guards / slot-id operands of in-flight steps must never go stale
    mgr.elastic.hold()
    states = [e._begin(max_new_tokens) for e in engines]
    try:
        active = [i for i, s in enumerate(states) if s is not None]
        while active:
            reqs = [(i, engines[i]._enqueue_step(states[i]))
                    for i in active]
            mgr.run_queued()
            active = [i for i, req in reqs
                      if engines[i]._finish_step(states[i], req)]
        return [engines[i]._finalize(s) if s is not None else {}
                for i, s in enumerate(states)]
    finally:
        mgr.elastic.release()
        for e in engines:
            e._in_run = False


def serve_continuous(engines: List["ServeEngine"],
                     max_new_tokens: int = 16
                     ) -> List[Dict[int, List[int]]]:
    """Per-request continuous-batching driver for *paged* engines sharing
    one GuardianManager.

    Every drain cycle each engine (1) retires rows whose request
    exhausted its budget — their virtual pages free immediately — and
    (2) refills idle rows from the admission queue (FIFO, arrival-gated,
    capped by ``max_inflight``).  A cycle with joiners dispatches a
    prefill for the joining rows *and* a decode for the continuing rows;
    the two steps park each other's rows on scratch pages (all mapping to
    the allocator-owned garbage page), so their pool writes are disjoint
    and the merged next-token vector is a single on-device ``where`` —
    the loop never syncs to the host.  All engines' steps ride ONE
    manager drain per cycle.

    Unlike the lockstep driver this one takes no elastic hold: paged
    resizes and compactions are page-table rewrites (host bookkeeping,
    zero relocation copy steps), so they are safe at every cycle
    boundary.  Returns one ``rid -> tokens`` dict per engine, in order;
    per-request generations are bit-identical to a solo lockstep run of
    the same prompt (uniform prompt padding assumed, as everywhere)."""
    if not engines:
        return []
    mgr = engines[0].manager
    if any(e.manager is not mgr for e in engines[1:]):
        raise ValueError("serve_continuous needs engines sharing one "
                         "GuardianManager (see make_shared_manager)")
    if not all(e.paged for e in engines):
        raise ValueError("serve_continuous drives paged engines; slab "
                         "engines lockstep through serve_engines")
    states = [e._cont_begin(max_new_tokens) for e in engines]
    stalled = 0
    try:
        while True:
            handles = []
            busy = False
            dispatched = False
            eligible_waiting = False
            for e, st in zip(engines, states):
                e._cont_leave(st)
                joiners = e._cont_join(st)
                handles.append((e, st) + e._cont_dispatch(st, joiners))
                if handles[-1][2] is not None or handles[-1][3] is not None:
                    dispatched = True
                if any(r is not None for r in st.rows) \
                        or e._cont_waiting(st):
                    busy = True
                eligible_waiting = eligible_waiting or any(
                    not r.done and not r.pages and r.arrive <= st.cycles
                    and e._admissible(r.tenant) for r in e._requests)
            if not busy:
                break
            # eligible requests exist but nothing could join or run for
            # several consecutive cycles: every tenant is page-full with
            # no active rows to free them — fail loudly, don't spin
            stalled = stalled + 1 if (not dispatched
                                      and eligible_waiting) else 0
            if stalled > 3:
                raise RuntimeError(
                    "serve_continuous stalled: waiting requests but no "
                    "tenant can allocate pages (extents too small?)")
            mgr.run_queued()
            for e, st, pre, dec, joiners, continuers, plen in handles:
                e._cont_finish(st, pre, dec, joiners, continuers, plen)
                e._cont_gauges(st)
        return [e._cont_finalize(st) for e, st in zip(engines, states)]
    finally:
        for e in engines:
            e._in_run = False


def _pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1


def _reset_seq_lens(meta):
    """A lockstep wave prefills EVERY row, so each row's sequence starts
    at 0 — without this an engine reused across run() calls would carry
    the previous wave's seq_lens into the new prefill (stale write
    positions + attention over dead tokens)."""
    if hasattr(meta, "seq_lens"):
        return dataclasses.replace(
            meta, seq_lens=jnp.zeros_like(meta.seq_lens))
    if hasattr(meta, "kv") and hasattr(meta.kv, "seq_lens"):
        kv = dataclasses.replace(
            meta.kv, seq_lens=jnp.zeros_like(meta.kv.seq_lens))
        return dataclasses.replace(meta, kv=kv)
    return meta


def _scrub_phys_pages(cache, phys):
    """Zero a set of *physical* pages of the global paged pool (axis 1 of
    the 5-dim k/v tensors) — the paged-mode eviction scrub."""
    if not phys:
        return cache
    idx = jnp.asarray(tuple(phys), jnp.int32)

    def zero(arr):
        z = jnp.zeros((arr.shape[0], len(phys), *arr.shape[2:]), arr.dtype)
        return arr.at[:, idx].set(z)

    return dataclasses.replace(cache, k=zero(cache.k), v=zero(cache.v))


def _scrub_slots(cache, base: int, size: int):
    """Zero a slot range [base, base+size) across every pool tensor of a
    cache pytree (axis 1 is the shared slot axis in all cache layouts —
    see kvcache.PagedKVCache / StateCache)."""
    def zero(arr):
        z = jnp.zeros((arr.shape[0], size, *arr.shape[2:]), arr.dtype)
        return jax.lax.dynamic_update_slice_in_dim(arr, z, base, axis=1)

    if hasattr(cache, "kv"):          # hybrid / encdec: recurse
        new = {"kv": _scrub_slots(cache.kv, base, size)}
        if hasattr(cache, "state"):
            new["state"] = _scrub_slots(cache.state, base, size)
        if hasattr(cache, "cross_k"):  # encdec cross-attention pools
            new["cross_k"] = zero(cache.cross_k)
            new["cross_v"] = zero(cache.cross_v)
        return dataclasses.replace(cache, **new)
    if hasattr(cache, "pools"):
        return dataclasses.replace(
            cache, pools={k: zero(v) for k, v in cache.pools.items()})
    return dataclasses.replace(cache, k=zero(cache.k), v=zero(cache.v))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--no-guard", action="store_true")
    ap.add_argument("--no-jit", action="store_true",
                    help="eager trusted steps (the bit-identical fallback "
                         "to the compiled step path)")
    ap.add_argument("--engines", type=int, default=1,
                    help="co-hosted engines sharing one manager; >1 "
                         "exercises the multi-engine fused decode")
    ap.add_argument("--policies", default="",
                    help="comma-separated per-tenant fence policies cycled "
                         "across tenants (e.g. 'modulo,check'); empty = "
                         "engine default (bitwise) for all")
    ap.add_argument("--classes", default="",
                    help="comma-separated per-tenant SLO classes cycled "
                         "across tenants — 'latency_critical'/'lc', "
                         "'best_effort'/'be', or '-' for class-less; "
                         "empty = class-less for all (the pre-class "
                         "behavior)")
    ap.add_argument("--bench-out", default=None,
                    help="append a `name,us_per_call,derived` bench CSV "
                         "row (per-token wall time) to this file — CI's "
                         "serve-smoke runs accumulate rows here and gate "
                         "them via benchmarks.check_regression")
    ap.add_argument("--bench-name", default="serve.smoke",
                    help="row name used with --bench-out")
    ap.add_argument("--trace-out", default=None,
                    help="write the manager's flight-recorder event trace "
                         "as Chrome/Perfetto trace_event JSON to this "
                         "path (load in ui.perfetto.dev)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.engines > 1:
        mgr = make_shared_manager(args.engines, max_batch=8,
                                  jit_trusted=not args.no_jit)
        engines = [ServeEngine(cfg, max_batch=8, max_len=256,
                               guard=not args.no_guard, manager=mgr)
                   for _ in range(args.engines)]
    else:
        engines = [ServeEngine(cfg, max_batch=8, max_len=256,
                               guard=not args.no_guard,
                               jit_steps=not args.no_jit)]
    pols = [FencePolicy(p.strip()) for p in args.policies.split(",")
            if p.strip()]
    aliases = {"lc": "latency_critical", "be": "best_effort", "-": None}
    classes = [aliases.get(c.strip(), c.strip())
               for c in args.classes.split(",") if c.strip()]
    per = max(engines[0]._pool_slots()
              // max(args.tenants * len(engines), 1) // 2, 2)
    for e, eng in enumerate(engines):
        for t in range(args.tenants):
            pol = pols[t % len(pols)] if pols else None
            cls = classes[t % len(classes)] if classes else None
            tenant = f"tenant{t}" if len(engines) == 1 \
                else f"e{e}.tenant{t}"
            eng.register_tenant(tenant, per, policy=pol, tenant_class=cls)
            if pol is not None:
                print(f"{tenant}: policy={pol.value}")
            if cls is not None:
                print(f"{tenant}: class={cls}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        t = i % args.tenants
        for e, eng in enumerate(engines):
            tenant = f"tenant{t}" if len(engines) == 1 \
                else f"e{e}.tenant{t}"
            prompt = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
            eng.submit(tenant, prompt)
    t0 = time.time()
    outs = serve_engines(engines, max_new_tokens=args.tokens)
    dt = time.time() - t0
    for e, out in enumerate(outs):
        for rid, toks in sorted(out.items()):
            print(f"engine{e} req {rid}: {toks[:8]}...")
    st = engines[0].manager.scheduler.stats
    n_out = sum(len(o) for o in outs)
    print(f"{n_out} requests, {args.tokens} tokens each, "
          f"{dt:.2f}s total, {sum(e.decode_steps for e in engines)} "
          f"decode steps, {int(st.total_launches)} scheduler launches, "
          f"mean step width {st.mean_batch_width:.1f}")
    if args.trace_out:
        trace = engines[0].manager.telemetry.trace
        with open(args.trace_out, "w") as fh:
            fh.write(trace.to_json())
        print(f"trace: {args.trace_out} ({len(trace)} events)")
    if args.bench_out:
        # per-token wall time: the one number the serve smokes gate on.
        # Includes trace/compile (cold start) — CI compares against a
        # baseline recorded the same way, normalized by the median ratio.
        n_tokens = max(n_out * args.tokens, 1)
        us = dt / n_tokens * 1e6
        row = (f"{args.bench_name},{us:.2f},"
               f"requests={n_out};tokens={n_tokens};"
               f"launches={int(st.total_launches)};"
               f"mean_width={st.mean_batch_width:.1f}")
        with open(args.bench_out, "a") as fh:
            fh.write(row + "\n")
        print(f"bench row -> {args.bench_out}: {row}")
    return outs[0]


if __name__ == "__main__":
    main()
