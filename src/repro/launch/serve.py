"""Multi-tenant serving engine — Guardian's spatial sharing applied to a
shared LM server.

One model, one KV pool, many mutually-untrusting tenants.  The pool's
sequence-slot space is carved into contiguous pow2 partitions (buddy
allocator) — one per tenant.  Every batched step carries **per-row fence
parameters**: a :class:`~repro.core.fence.FenceTable` holds one
``(base, mask)`` int32 row per tenant, and each prefill/decode step gathers
the rows for its batch through a tenant-id column — row b of the batch
belongs to tenant t(b), so the slot index of row b is fenced with t(b)'s
(base, mask).  Even a corrupted scheduler
or a forged slot id can only wrap inside the owning tenant's slots — the
serving-plane equivalent of the paper's sandboxed kernels.

Fault containment (DESIGN.md §Fault-containment): the engine drives a
:class:`~repro.core.quarantine.QuarantineStateMachine` — quarantined
tenants' submissions are rejected, their pending requests re-route to
co-tenants, and eviction scrubs + reclaims their pool partition.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --tenants 3 --requests 6 --tokens 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.core.fence import FenceParams, FencePolicy, FenceTable
from repro.core.partition import PartitionBoundsTable
from repro.core.quarantine import QuarantineStateMachine
from repro.models import get_model
from repro.models.guard import GuardSpec


@dataclasses.dataclass
class Request:
    tenant: str
    rid: int
    prompt: np.ndarray
    slot: int                      # absolute slot in the shared pool
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching (fixed-slot) multi-tenant server."""

    def __init__(self, cfg, *, max_batch: int = 8, max_len: int = 256,
                 policy: FencePolicy = FencePolicy.BITWISE,
                 guard: bool = True, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.policy = policy
        self.guard_enabled = guard
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = self.api.init(jax.random.PRNGKey(seed))
        # pool = 2x the batch slots: the upper half is the engine's scratch
        # partition where idle batch rows park (their fenced writes must
        # never land in a tenant's slots).
        def pow2(n):
            return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1
        n_slots = 2 * pow2(max_batch)
        if cfg.family == "ssm":
            self.cache = self.api.init_cache(max_batch, slots=n_slots)
        else:
            self.cache = self.api.init_cache(max_batch, max_len,
                                             dtype=jnp.float32,
                                             slots=n_slots)
        slots = self._pool_slots()
        self.bounds = PartitionBoundsTable(slots)
        self._scratch = self.bounds.create("__scratch", slots // 2)
        # fault containment: lifecycle gate for the serving plane (the
        # engine shares the state machine with the GuardianManager but
        # drives transitions itself — violations here are scheduler-level,
        # e.g. an upstream fraud signal or a manager-side quarantine event)
        self.quarantine = QuarantineStateMachine()
        self.rejected: List[int] = []     # rids dropped by quarantine
        self._ftable: Optional[FenceTable] = None
        self._ftable_key: Tuple = ()
        self._ftable_row: Dict[str, int] = {}
        self._tenant_of_slot: Dict[int, str] = {}
        self._requests: List[Request] = []
        self._rid = 0
        self._row_slots = np.zeros((max_batch,), np.int32)
        self._row_req: List[Optional[Request]] = [None] * max_batch
        self.decode_steps = 0

    def _pool_slots(self) -> int:
        c = self.cache
        if hasattr(c, "k"):
            return c.k.shape[1]
        if hasattr(c, "pools"):
            return next(iter(c.pools.values())).shape[1]
        return c.kv.k.shape[1]

    # ------------------------------------------------------------------ #
    def register_tenant(self, name: str, slots: int):
        new_record = self.quarantine.record_of(name) is None
        self.quarantine.admit(name)      # refuses EVICTED ids
        try:
            return self.bounds.create(name, slots)
        except Exception:
            if new_record:               # no phantom ACTIVE record
                self.quarantine.forget(name)
            raise

    def quarantine_tenant(self, name: str, reason: str = "") -> List[int]:
        """Reject the tenant: pending requests are dropped (their batch
        rows re-route to co-tenants on the next ``run``), new submissions
        raise.  Returns the dropped request ids."""
        self.quarantine.quarantine(name, reason=reason)
        dropped = [r.rid for r in self._requests
                   if r.tenant == name and not r.done]
        self._requests = [r for r in self._requests
                          if r.done or r.tenant != name]
        self.rejected.extend(dropped)
        return dropped

    def evict_tenant(self, name: str) -> None:
        """Scrub the tenant's pool slots and return its partition to the
        buddy allocator; the freed block serves the next registration."""
        part = self.bounds.lookup(name)
        self.quarantine.evict(name)
        self.cache = _scrub_slots(self.cache, part.base, part.size)
        self.bounds.destroy(name)
        self._ftable = None              # bounds changed: rebuild on demand

    def readmit_tenant(self, name: str) -> None:
        self.quarantine.readmit(name)

    def submit(self, tenant: str, prompt: np.ndarray) -> int:
        self.quarantine.check_admission(tenant, "submit")
        part = self.bounds.lookup(tenant)
        used = {r.slot for r in self._requests if not r.done
                and r.tenant == tenant}
        free = [s for s in range(part.base, part.end) if s not in used]
        if not free:
            raise RuntimeError(f"tenant {tenant}: no free slots")
        rid = self._rid
        self._rid += 1
        self._requests.append(Request(tenant=tenant, rid=rid,
                                      prompt=np.asarray(prompt),
                                      slot=free[0]))
        return rid

    # ------------------------------------------------------------------ #
    def _fence_table(self) -> Tuple[FenceTable, Dict[str, int]]:
        """Stacked (T, 2) fence rows for all registered tenants (incl. the
        scratch partition), rebuilt only when the tenant set changes.  The
        table validates pow2 sizes on the host before staging — a traced
        FenceParams.mask cannot (fence.require_pow2_sizes contract)."""
        ids = tuple(sorted(self.bounds.tenants()))
        parts = [self.bounds.lookup(t) for t in ids]
        # key includes the bounds: a tenant destroyed and re-registered
        # under the same name may get a different partition
        key = tuple((t, p.base, p.size) for t, p in zip(ids, parts))
        if self._ftable is None or self._ftable_key != key:
            self._ftable = FenceTable.from_partitions(parts)
            self._ftable_key = key
            self._ftable_row = {t: i for i, t in enumerate(ids)}
        return self._ftable, self._ftable_row

    def _guard_for_rows(self, rows: List[Request]) -> Optional[GuardSpec]:
        if not self.guard_enabled:
            return None
        table, row_of = self._fence_table()
        # tenant-id column: batch row b -> fence-table row of its tenant
        # (idle rows park in the engine's scratch partition)
        cols = np.full((self.max_batch,), row_of["__scratch"], np.int32)
        for i, r in enumerate(rows):
            if r is not None:
                cols[i] = row_of[r.tenant]
        slot_params = table.gather(jnp.asarray(cols))
        pages = self.cache.kv.pages_per_slot if hasattr(self.cache, "kv") \
            else (self.cache.pages_per_slot if hasattr(self.cache, "k")
                  else 1)

        def pow2(n):
            return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1
        return GuardSpec(
            policy=self.policy,
            vocab=FenceParams(base=0, size=pow2(self.cfg.vocab)),
            kv=slot_params,
            state=slot_params,
            expert=(FenceParams(base=0, size=pow2(
                self.cfg.moe.num_experts)) if self.cfg.moe else None),
            page=FenceParams(base=0, size=pow2(max(pages, 1))),
        )

    def _assign_rows(self) -> List[Request]:
        """Round-robin across tenants (paper §4.2.4) for idle rows.
        Quarantined tenants' requests never occupy a row — their slots
        re-route to admissible co-tenants."""
        active = [r for r in self._requests if not r.done
                  and _admissible(self.quarantine, r.tenant)]
        by_tenant: Dict[str, List[Request]] = {}
        for r in active:
            by_tenant.setdefault(r.tenant, []).append(r)
        order: List[Request] = []
        while any(by_tenant.values()):
            for t in sorted(by_tenant):
                if by_tenant[t]:
                    order.append(by_tenant[t].pop(0))
        return order[: self.max_batch]

    def run(self, max_new_tokens: int = 16) -> Dict[int, List[int]]:
        """Prefill all pending, then decode until done/limit."""
        rows = self._assign_rows()
        if not rows:
            return {}
        B = self.max_batch
        # build padded prompt batch
        plen = max(len(r.prompt) for r in rows)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(rows):
            toks[i, :len(r.prompt)] = r.prompt
        slot_ids = np.full((B,), self._scratch.base, np.int32)
        for i, r in enumerate(rows):
            slot_ids[i] = r.slot
        cache = dataclasses.replace(
            self._cache_with_slots(jnp.asarray(slot_ids)))
        guard = self._guard_for_rows(rows + [None] * (B - len(rows)))

        if self.cfg.family == "encdec":
            batch = {"src": jnp.zeros(
                (B, 16, self.cfg.d_model), jnp.float32),
                "tgt": jnp.asarray(toks)}
        else:
            batch = {"tokens": jnp.asarray(toks)}
        cache, logits = self.api.prefill(self.params, cache, batch,
                                         guard=guard)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(max_new_tokens):
            for i, r in enumerate(rows):
                r.generated.append(int(nxt[i]))
            cache, logits = self.api.decode(self.params, cache, nxt,
                                            guard=guard)
            self.decode_steps += 1
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r in rows:
            r.done = True
        self.cache = cache
        return {r.rid: r.generated for r in rows}

    def _cache_with_slots(self, slot_ids):
        c = self.cache
        if hasattr(c, "slot_ids"):
            return dataclasses.replace(c, slot_ids=slot_ids)
        if hasattr(c, "kv"):   # hybrid / encdec
            kv = dataclasses.replace(c.kv, slot_ids=slot_ids)
            if hasattr(c, "state"):
                st = dataclasses.replace(c.state, slot_ids=slot_ids)
                return dataclasses.replace(c, kv=kv, state=st)
            return dataclasses.replace(c, kv=kv)
        return c


def _admissible(machine: QuarantineStateMachine, tenant: str) -> bool:
    state = machine.state_of(tenant)
    return state is None or state.admissible


def _scrub_slots(cache, base: int, size: int):
    """Zero a slot range [base, base+size) across every pool tensor of a
    cache pytree (axis 1 is the shared slot axis in all cache layouts —
    see kvcache.PagedKVCache / StateCache)."""
    def zero(arr):
        z = jnp.zeros((arr.shape[0], size, *arr.shape[2:]), arr.dtype)
        return jax.lax.dynamic_update_slice_in_dim(arr, z, base, axis=1)

    if hasattr(cache, "kv"):          # hybrid / encdec: recurse
        new = {"kv": _scrub_slots(cache.kv, base, size)}
        if hasattr(cache, "state"):
            new["state"] = _scrub_slots(cache.state, base, size)
        if hasattr(cache, "cross_k"):  # encdec cross-attention pools
            new["cross_k"] = zero(cache.cross_k)
            new["cross_v"] = zero(cache.cross_v)
        return dataclasses.replace(cache, **new)
    if hasattr(cache, "pools"):
        return dataclasses.replace(
            cache, pools={k: zero(v) for k, v in cache.pools.items()})
    return dataclasses.replace(cache, k=zero(cache.k), v=zero(cache.v))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--no-guard", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    eng = ServeEngine(cfg, max_batch=8, max_len=256,
                      guard=not args.no_guard)
    per = max(eng._pool_slots() // max(args.tenants, 1) // 2, 2)
    for t in range(args.tenants):
        eng.register_tenant(f"tenant{t}", per)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        tenant = f"tenant{i % args.tenants}"
        prompt = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
        eng.submit(tenant, prompt)
    t0 = time.time()
    out = eng.run(max_new_tokens=args.tokens)
    dt = time.time() - t0
    for rid, toks in sorted(out.items()):
        print(f"req {rid}: {toks[:8]}...")
    print(f"{len(out)} requests, {args.tokens} tokens each, "
          f"{dt:.2f}s total, {eng.decode_steps} decode steps")


if __name__ == "__main__":
    main()
