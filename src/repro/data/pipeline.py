"""Deterministic sharded data pipeline.

Restart-exact: batch contents are a pure function of (seed, step,
shard_index), so an elastic restart at step k reproduces the exact token
stream without any iterator state in the checkpoint.  Two sources:

* :class:`SyntheticLM` — seeded token stream (zipfian unigram + markov
  bigram mixture so the loss actually decreases during the examples).
* :class:`FileTokens` — memory-mapped token file (one uint16/uint32 array),
  deterministic strided windows.

Per-host sharding: each host materializes only its ``(host_index,
host_count)`` slice of the global batch — the standard multi-pod input
pattern (no host ever holds the global batch).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0, \
            (self.global_batch, self.host_count)
        return self.global_batch // self.host_count


class SyntheticLM:
    """Seeded synthetic LM stream with learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # zipfian unigram + a deterministic "grammar": each token prefers
        # a fixed successor, so a model can learn p(next|cur).
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._succ = rng.permutation(v).astype(np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4_096 + cfg.host_index)
        B, S = cfg.local_batch, cfg.seq_len
        out = np.empty((B, S + 1), np.int32)
        cur = rng.choice(cfg.vocab, size=B, p=self._unigram)
        out[:, 0] = cur
        follow = rng.random((B, S)) < 0.7   # 70% grammar, 30% noise
        noise = rng.choice(cfg.vocab, size=(B, S), p=self._unigram)
        for t in range(S):
            cur = np.where(follow[:, t], self._succ[cur], noise[:, t])
            out[:, t + 1] = cur
        return {"tokens": out}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class FileTokens:
    """Deterministic windows over a memory-mapped token array."""

    def __init__(self, cfg: DataConfig, path: str, dtype=np.uint16):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // cfg.seq_len

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = cfg.local_batch, cfg.seq_len
        base = (step * cfg.global_batch + cfg.host_index * B)
        idx = (base + np.arange(B)) % max(self._n_windows, 1)
        out = np.empty((B, S + 1), np.int32)
        for i, w in enumerate(idx):
            start = w * S
            out[i] = self._data[start:start + S + 1]
        return {"tokens": out}


def make_source(cfg: DataConfig, path: Optional[str] = None):
    return FileTokens(cfg, path) if path else SyntheticLM(cfg)
