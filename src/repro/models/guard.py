"""GuardSpec — how Guardian fencing threads through model data paths.

The paper fences every *dynamically computed address* in GPU kernels.  In
the model stack the dynamically computed addresses are:

    vocab    token ids            -> embedding-row gather
    kv       sequence-slot ids +  -> paged-KV pool reads/writes
             page ids
    state    state-slot ids       -> SSM/recurrent state pool reads/writes
    expert   expert ids           -> MoE dispatch offsets

A :class:`GuardSpec` carries one :class:`FenceParams` per index space plus
the :class:`FencePolicy`; ``fence(spec, which, idx)`` applies the configured
fence.  ``spec=None`` (or a missing space) is the paper's *standalone
fast-path*: the index passes through untouched and the fence instructions
are never emitted into the compiled step — bit-identical to a native build.

This gives each tenant's model step the same guarantee as a sandboxed PTX
kernel: no matter how corrupted the scheduler state, page tables, or router
outputs are, every arena access lands inside the tenant's own partition.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fence import (
    FenceParams,
    FencePolicy,
    apply_fence,
    apply_fence_mixed,
)

#: Index spaces whose params are *per-batch-row* (gathered through a
#: tenant-id column) — the spaces row-mixed policies apply to.  The global
#: spaces (vocab / expert / page) are shared read-only index spaces fenced
#: with the engine-level default policy.
ROW_SPACES = ("kv", "state")


@dataclasses.dataclass(frozen=True)
class GuardSpec:
    policy: FencePolicy = FencePolicy.BITWISE
    vocab: Optional[FenceParams] = None
    kv: Optional[FenceParams] = None
    state: Optional[FenceParams] = None
    expert: Optional[FenceParams] = None
    page: Optional[FenceParams] = None   # logical->physical page ids in slab
    #: per-batch-row policy codes (FencePolicy.code) for row-mixed batches;
    #: applies to ROW_SPACES only.  None -> ``policy`` everywhere.
    row_policy: Optional[jax.Array] = None
    #: virtual->physical page translation for the *global* paged pool
    #: (``(P_total,)`` int32, manager-owned).  Tenant partitions live in a
    #: virtual page space; fenced virtual ids index this map, so elastic
    #: compaction is a host rewrite of the map — no KV bytes move.  None
    #: for the slab layout (and for slab-relative "page" fencing).
    page_map: Optional[jax.Array] = None

    def params_for(self, which: str) -> Optional[FenceParams]:
        return getattr(self, which)


# GuardSpec travels as a jit operand on the jitted trusted-step path (the
# serving engine's prefill/decode launches): the per-space FenceParams are
# pytree children (themselves splitting array bounds from static ints — see
# fence._fence_params_flatten), the policy is aux data.
jax.tree_util.register_pytree_node(
    GuardSpec,
    lambda g: ((g.vocab, g.kv, g.state, g.expert, g.page, g.row_policy,
                g.page_map),
               g.policy),
    lambda policy, ch: GuardSpec(policy, *ch),
)


def _broadcast_params(params: FenceParams, idx: jax.Array) -> FenceParams:
    """Per-row (B,) bound arrays against a (B, ...) index: append trailing
    singleton axes so the fence broadcasts row-wise (the paged serve path
    fences a (B, P) page table with per-row tenant extents).  Scalar and
    already-matching params pass through untouched."""
    base = params.base
    if not isinstance(base, (jax.Array, np.ndarray)) or base.ndim == 0 \
            or base.ndim >= idx.ndim:
        return params

    def expand(v):
        if isinstance(v, (jax.Array, np.ndarray)) and v.ndim:
            return v.reshape(v.shape + (1,) * (idx.ndim - v.ndim))
        return v

    return FenceParams(base=expand(params.base), size=expand(params.size),
                       magic_m=expand(params.magic_m),
                       magic_s=expand(params.magic_s))


def fence(spec: Optional[GuardSpec], which: str, idx: jax.Array) -> jax.Array:
    """Fence ``idx`` into the partition for index-space ``which``.

    No-op (native fast path) when spec is None or the space is unguarded.
    CHECK policy degrades to clamping here (detection/attribution for the
    serving plane is host-side from the same bounds — the `ok` predicate
    would be a scan tracer inside scan-over-layers models)."""
    if spec is None:
        return idx
    params = spec.params_for(which)
    if params is None:
        return idx
    params = _broadcast_params(params, idx)
    if spec.row_policy is not None and which in ROW_SPACES:
        row_policy = spec.row_policy
        if row_policy.ndim < idx.ndim:
            row_policy = row_policy.reshape(
                row_policy.shape + (1,) * (idx.ndim - row_policy.ndim))
        fenced, _ok = apply_fence_mixed(row_policy, idx, params)
    else:
        fenced, _ok = apply_fence(spec.policy, idx, params)
    return fenced.astype(idx.dtype)


def fence_pages(spec: Optional[GuardSpec],
                virt: jax.Array) -> jax.Array:
    """Resolve already-fenced *virtual* page ids to physical pages of the
    global paged pool: translate through the manager-owned ``page_map``,
    then clamp into the pool extent (space "page" — defense in depth: even
    a corrupted map entry stays inside the pool tensor).  Without a
    ``page_map`` this is the slab-relative "page" fence unchanged."""
    if spec is None:
        return virt
    if spec.page_map is not None:
        virt = jnp.take(spec.page_map, virt, axis=0).astype(virt.dtype)
    return fence(spec, "page", virt)


def full_guard(policy: FencePolicy = FencePolicy.BITWISE, *,
               vocab_slots: int = 0, kv_slots: int = 0,
               state_slots: int = 0, expert_slots: int = 0,
               page_slots: int = 0, base: int = 0) -> GuardSpec:
    """Convenience: guard every space with a [base, base+n) partition."""
    def p(n):
        return FenceParams(base=base, size=n) if n else None
    return GuardSpec(policy=policy, vocab=p(vocab_slots), kv=p(kv_slots),
                     state=p(state_slots), expert=p(expert_slots),
                     page=p(page_slots))
