from repro.models.api import ModelAPI, get_model
from repro.models.guard import GuardSpec, fence, full_guard

__all__ = ["ModelAPI", "get_model", "GuardSpec", "fence", "full_guard"]
