"""Shared model building blocks — pure JAX, pytree params, shardable.

Conventions:
* params are nested dicts of jnp arrays; a parallel tree of *logical axis
  tuples* (see ``repro.distributed.sharding``) drives pjit placement.
* activations: (batch, seq, d_model); attention internals
  (batch, seq, heads, head_dim).
* every data-dependent index op takes the optional ``guard`` spec
  (``repro.models.guard``) so Guardian fencing is a first-class switch.
* attention is **chunked/online-softmax** (flash-style) so 32k prefill
  never materializes an (S, S) score matrix.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fence import guarded_take
from repro.models.guard import GuardSpec, fence

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dt)


def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_axes(cfg: ModelConfig) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": (None,), "bias": (None,)}
    return {"scale": (None,)}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """M-RoPE (qwen2-vl): positions3 (B, S, 3) — temporal/height/width ids.

    The D/2 frequency slots are split into three sections; each section
    rotates by its own position component.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)       # (half,)
    sec_id = np.concatenate([
        np.full((s,), i) for i, s in enumerate(sections)])       # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(jnp.asarray(sec_id, jnp.int32)[None, None, :],
                         (*positions3.shape[:2], half)),
        axis=-1)                                                 # (B,S,half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """qwen2-vl default split of the D/2 slots: 1/4 temporal, 3/8, 3/8."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — online softmax over KV blocks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, KH, G, D)  k: (B, Skv, KH, D) -> (B, KH, G, Sq, Skv)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _block_mask(qi, ki, q_chunk, kv_chunk, q_off, kv_valid,
                causal: bool, window: int, batched: bool):
    """Mask for one (q-block, kv-block) pair.

    batched=False (training: uniform offsets, full kv) -> (qc, kc) — keeps
    the mask batch-free so GSPMD never materializes a (B, S, S) predicate.
    batched=True  -> (B, qc, kc).
    """
    q_ids = jnp.arange(q_chunk, dtype=jnp.int32)
    kv_pos = ki * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
    if not batched:
        q_pos = q_off + qi * q_chunk + q_ids                  # (qc,)
        mask = kv_pos[None, :] < kv_valid
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        return mask                                           # (qc,kc)
    q_pos = q_off[:, None] + qi * q_chunk + q_ids[None, :]     # (B,qc)
    mask = jnp.broadcast_to(kv_pos[None, None, :] < kv_valid[:, None, None],
                            (q_pos.shape[0], q_chunk, kv_chunk))
    if causal:
        mask = mask & (kv_pos[None, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (kv_pos[None, None, :] > q_pos[:, :, None] - window)
    return mask                                               # (B,qc,kc)


def _apply_mask(s, mask):
    """s (B,KH,G,qc,kc); mask (qc,kc) or (B,qc,kc)."""
    if mask.ndim == 2:
        return jnp.where(mask[None, None, None], s, NEG_INF)
    return jnp.where(mask[:, None, None], s, NEG_INF)


def _c(x, spec):
    """Sharding constraint that no-ops outside a mesh context (CPU smoke
    tests) but pins loop-carry shardings in the dry-run/production path —
    GSPMD's propagation through while carries is weak, and an unpinned
    carry silently replicates the batch (16x flops/memory)."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def _flash_specs(static):
    """(acc, m/denom/lse, out-stack, lse-stack) PartitionSpecs."""
    return static[7] if len(static) > 7 and static[7] is not None else \
        (None, None, None, None)


def _flash_fwd_pass(static, q, k, v, q_off, kv_valid):
    (causal, window, q_chunk, kv_chunk, nq, nk, batched) = static[:7]
    spec_acc, spec_m, spec_outs, spec_lses = _flash_specs(static)
    B, _, qc, KH, G, D = q.shape
    scale = 1.0 / math.sqrt(D)

    def q_block(qi):
        q_blk = q[:, qi]

        def kv_block(carry, ki):
            acc, m, denom = carry
            k_blk, v_blk = k[:, ki], v[:, ki]
            s = _gqa_scores(q_blk, k_blk) * scale   # (B,KH,G,qc,kc)
            mask = _block_mask(qi, ki, q_chunk, kv_chunk, q_off, kv_valid,
                               causal, window, batched)
            s = _apply_mask(s, mask)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (_c(acc, spec_acc), _c(m_new, spec_m),
                    _c(denom, spec_m)), None

        acc0 = _c(jnp.zeros((B, KH, G, qc, D), jnp.float32), spec_acc)
        m0 = _c(jnp.full((B, KH, G, qc), NEG_INF, jnp.float32), spec_m)
        d0 = _c(jnp.zeros((B, KH, G, qc), jnp.float32), spec_m)
        (acc, m, denom), _ = jax.lax.scan(
            kv_block, (acc0, m0, d0), jnp.arange(nk))
        denom = jnp.maximum(denom, 1e-30)
        out = acc / denom[..., None]
        lse = m + jnp.log(denom)                    # (B,KH,G,qc)
        return _c(out, spec_acc), _c(lse, spec_m)

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    # outs (nq,B,KH,G,qc,D); lses (nq,B,KH,G,qc)
    return _c(outs, spec_outs), _c(lses, spec_lses)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(static, q, k, v, q_off, kv_valid):
    outs, _ = _flash_fwd_pass(static, q, k, v, q_off, kv_valid)
    return outs


def _flash_fwd(static, q, k, v, q_off, kv_valid):
    outs, lses = _flash_fwd_pass(static, q, k, v, q_off, kv_valid)
    return outs, (q, k, v, q_off, kv_valid, outs, lses)


def _flash_bwd(static, res, g):
    """FlashAttention backward: recompute per-block scores from (q,k,lse);
    never materializes more than one (qc,kc) block per (KH,G)."""
    (causal, window, q_chunk, kv_chunk, nq, nk, batched) = static[:7]
    spec_acc, spec_m, spec_outs, spec_lses = _flash_specs(static)
    q, k, v, q_off, kv_valid, outs, lses = res
    B, _, qc, KH, G, D = q.shape
    scale = 1.0 / math.sqrt(D)
    # delta = rowsum(dO * O): (nq,B,KH,G,qc)
    delta = _c(jnp.sum(g.astype(jnp.float32) * outs, axis=-1), spec_lses)

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        q_blk = q[:, qi].astype(jnp.float32)        # (B,qc,KH,G,D)
        g_blk = g[qi].astype(jnp.float32)           # (B,KH,G,qc,D)
        lse_blk = lses[qi]                          # (B,KH,G,qc)
        delta_blk = delta[qi]

        def kv_block(dq_carry, ki):
            dq_blk, dk_acc, dv_acc = dq_carry
            k_blk = k[:, ki].astype(jnp.float32)    # (B,kc,KH,D)
            v_blk = v[:, ki].astype(jnp.float32)
            s = _gqa_scores(q_blk, k_blk) * scale   # (B,KH,G,qc,kc)
            mask = _block_mask(qi, ki, q_chunk, kv_chunk, q_off, kv_valid,
                               causal, window, batched)
            s = _apply_mask(s, mask)
            p = jnp.exp(s - lse_blk[..., None])     # (B,KH,G,qc,kc)
            # dv += p^T g
            dv = jnp.einsum("bkgqs,bkgqd->bskd", p, g_blk)
            # dp = g v^T
            dp = jnp.einsum("bkgqd,bskd->bkgqs", g_blk, v_blk)
            ds = p * (dp - delta_blk[..., None]) * scale
            dq = jnp.einsum("bkgqs,bskd->bqkgd", ds, k_blk)
            dk = jnp.einsum("bkgqs,bqkgd->bskd", ds, q_blk)
            dk_acc = dk_acc.at[:, ki].add(dk)
            dv_acc = dv_acc.at[:, ki].add(dv)
            return (dq_blk + dq, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, qc, KH, G, D), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_block, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_blk

    kv_spec = None
    if spec_acc is not None:
        try:
            from jax.sharding import PartitionSpec as _P
            ps = list(spec_acc) + [None] * (6 - len(spec_acc))
            kv_spec = _P(ps[0], None, None, ps[1], None)   # (B,nk,kc,KH,D)
        except TypeError:
            kv_spec = None
    dk0 = _c(jnp.zeros(k.shape, jnp.float32), kv_spec)
    dv0 = _c(jnp.zeros(v.shape, jnp.float32), kv_spec)
    (dk, dv), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1)                   # (B,nq,qc,KH,G,D)
    zero_off = np.zeros(jnp.shape(q_off), jax.dtypes.float0)
    zero_len = np.zeros(jnp.shape(kv_valid), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero_off, zero_len)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Skv, KH, D)
    v: jax.Array,                  # (B, Skv, KH, D)
    *,
    causal: bool = True,
    q_offset: Any = 0,             # absolute position of q[0] (int or (B,))
    window: int = 0,               # 0 = full; else sliding window
    kv_len: Optional[jax.Array] = None,   # (B,) valid KV length (masking)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    rules: Any = None,             # ShardingRules: pins loop-carry shardings
) -> jax.Array:
    """Blockwise flash attention with online softmax + recomputation
    backward (custom_vjp) — never materializes an (S, S) score matrix in
    either pass.  GQA native: H = KH * G query heads share KH kv heads.
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pq, Skv + pk
    nq, nk = Sq_p // q_chunk, Skv_p // kv_chunk

    qb = q.reshape(B, nq, q_chunk, KH, G, D)
    kb = k.reshape(B, nk, kv_chunk, KH, D)
    vb = v.reshape(B, nk, kv_chunk, KH, D)

    # batch-free masks when offsets are uniform and kv is full (training)
    batched = not (isinstance(q_offset, int) and kv_len is None)
    if batched:
        q_off = jnp.asarray(q_offset, jnp.int32)
        if q_off.ndim == 0:
            q_off = jnp.broadcast_to(q_off, (B,))
        kv_valid = (jnp.full((B,), Skv, jnp.int32) if kv_len is None
                    else kv_len.astype(jnp.int32))
    else:
        q_off = jnp.int32(q_offset)
        kv_valid = jnp.int32(Skv)   # padded tail masked by causal+valid
        if pk:
            kv_valid = jnp.int32(Skv)

    specs = None
    if rules is not None:
        from jax.sharding import PartitionSpec as _P
        ba = rules.lookup("batch")
        ma = rules.lookup("kv_heads")
        specs = (_P(ba, ma, None, None, None),    # acc   (B,KH,G,qc,D)
                 _P(ba, ma, None, None),          # m/lse (B,KH,G,qc)
                 _P(None, ba, ma, None, None, None),  # outs stack
                 _P(None, ba, ma, None, None))        # lse stack
        qb = _c(qb, _P(ba, None, None, ma, None, None))
        kb = _c(kb, _P(ba, None, None, ma, None))
        vb = _c(vb, _P(ba, None, None, ma, None))

    static = (causal, window, q_chunk, kv_chunk, nq, nk, batched, specs)
    outs = _flash(static, qb, kb, vb, q_off, kv_valid)
    # outs (nq,B,KH,G,qc,D) -> (B, Sq, H, D)
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(
        B, Sq_p, H, D)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,                  # (B, 1, H, D)
    k: jax.Array,                  # (B, Skv, KH, D)
    v: jax.Array,                  # (B, Skv, KH, D)
    kv_len: jax.Array,             # (B,)
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention over a (gathered) KV history.

    One un-chunked pass: scores are (B, H, 1, Skv) — linear in Skv, fine.
    """
    B, _, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, KH, G, D)
    s = _gqa_scores(qg, k) * scale           # (B,KH,G,1,Skv)
    pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = pos[None, :] < kv_len[:, None]     # (B,Skv)
    if window:
        mask = mask & (pos[None, :] > kv_len[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + chunked attention)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {
        "wq": dense_init(k1, d, cfg.q_dim, dtype),
        "wk": dense_init(k2, d, cfg.kv_dim, dtype),
        "wv": dense_init(k3, d, cfg.kv_dim, dtype),
        "wo": dense_init(k4, cfg.q_dim, d, dtype,
                         scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def attention_axes(cfg: ModelConfig) -> Params:
    p: Params = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads",)
        p["bk"] = ("kv_heads",)
        p["bv"] = ("kv_heads",)
    return p


def qkv_proj(cfg: ModelConfig, p: Params, x: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,d) -> q (B,S,H,D), k/v (B,S,KH,D)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def out_proj(cfg: ModelConfig, p: Params, o: jax.Array) -> jax.Array:
    B, S = o.shape[:2]
    return o.reshape(B, S, cfg.q_dim) @ p["wo"]


def positions_rope(cfg: ModelConfig, q, k, positions):
    """Apply (M-)RoPE to q,k given positions ((B,S) or (B,S,3))."""
    if cfg.mrope:
        secs = mrope_sections(cfg.head_dim)
        if positions.ndim == 2:  # text-only: all three components equal
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
        q = apply_mrope(q, positions, cfg.rope_theta, secs)
        k = apply_mrope(k, positions, cfg.rope_theta, secs)
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             dtype=None) -> Params:
    dtype = dtype or dtype_of(cfg)
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act == "silu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wg": dense_init(k1, d, d_ff, dtype),
                "wu": dense_init(k2, d, d_ff, dtype),
                "wd": dense_init(k3, d_ff, d, dtype,
                                 scale=1.0 / math.sqrt(2 * cfg.n_layers))}
    k1, k2 = jax.random.split(key, 2)
    return {"wu": dense_init(k1, d, d_ff, dtype),
            "wd": dense_init(k2, d_ff, d, dtype,
                             scale=1.0 / math.sqrt(2 * cfg.n_layers))}


def mlp_axes(cfg: ModelConfig) -> Params:
    if cfg.act == "silu":
        return {"wg": ("embed", "ffn"), "wu": ("embed", "ffn"),
                "wd": ("ffn", "embed")}
    return {"wu": ("embed", "ffn"), "wd": ("ffn", "embed")}


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wu"]) @ p["wd"]


# ---------------------------------------------------------------------------
# Embedding + LM head (fenced token gather — Guardian vocab space)
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p: Params = {"table": embed_init(k1, cfg.vocab, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, cfg.d_model, cfg.vocab, dtype)
    return p


def embedding_axes(cfg: ModelConfig) -> Params:
    p: Params = {"table": ("vocab", "embed_nofsdp")}
    if not cfg.tie_embeddings:
        p["head"] = ("embed_nofsdp", "vocab")
    return p


def embed_tokens(p: Params, tokens: jax.Array,
                 guard: Optional[GuardSpec] = None) -> jax.Array:
    """Token-id gather.  With a guard, ids are fenced into the tenant's
    vocab partition (token ids are untrusted request data)."""
    ids = fence(guard, "vocab", tokens.astype(jnp.int32))
    return jnp.take(p["table"], ids, axis=0)


def lm_logits(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["table"].T
    return x @ p["head"]


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL.  logits (B,S,V) any float dtype; labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
