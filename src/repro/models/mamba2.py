"""Mamba2 block (SSD) — the zamba2-7b backbone layer.

Block: in_proj -> [z | x | B | C | dt], causal conv1d over (x,B,C),
SiLU, chunked SSD scan, D skip, gated RMSNorm, out_proj.

Serving state per (layer, request): the SSM state (H, N, P) plus the conv
tail (conv_width-1, conv_dim) — both live in the Guardian-partitioned
state pool (fenced slot ids, space "state").
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssd import ssd_chunked, ssd_step

Params = Dict[str, Any]

P_HEAD = 64  # SSD head size (Mamba2 default)


def dims(cfg: ModelConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = max(d_in // P_HEAD, 1)
    n = s.state_dim
    conv_dim = d_in + 2 * n * 1  # single B/C group
    return {"d_in": d_in, "heads": heads, "n": n, "conv_dim": conv_dim,
            "p": d_in // heads}


def block_init(key, cfg: ModelConfig) -> Params:
    dm = dims(cfg)
    d, d_in, heads, n = cfg.d_model, dm["d_in"], dm["heads"], dm["n"]
    conv_dim = dm["conv_dim"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = L.dtype_of(cfg)
    proj_out = 2 * d_in + 2 * n + heads  # z | x | B | C | dt
    return {
        "in_proj": L.dense_init(k1, d, proj_out, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm.conv_width, conv_dim),
                                     jnp.float32)
                   / math.sqrt(cfg.ssm.conv_width)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads).astype(jnp.float32)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm_g": jnp.ones((d_in,), jnp.float32),
        "out_proj": L.dense_init(k3, d_in, d, dt,
                                 scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def block_axes(cfg: ModelConfig) -> Params:
    return {
        "in_proj": ("embed", "ffn"),
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "norm_g": ("ffn",),
        "out_proj": ("ffn", "embed"),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    dm = dims(cfg)
    d_in, n, heads = dm["d_in"], dm["n"], dm["heads"]
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + dm["conv_dim"]]
    dt_raw = proj[..., d_in + dm["conv_dim"]:]
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xbc (B,S,C), w (K,C).  ``tail`` is the
    previous (K-1, C) inputs (decode); returns (out, new_tail)."""
    K = w.shape[0]
    B, S, C = xbc.shape
    if tail is None:
        tail_in = jnp.zeros((B, K - 1, C), xbc.dtype)
    else:
        tail_in = tail.astype(xbc.dtype)
    xp = jnp.concatenate([tail_in, xbc], axis=1)      # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + S].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_tail = xp[:, S:]                              # last K-1 inputs
    return out.astype(xbc.dtype), new_tail


def _gated_rmsnorm(y: jax.Array, z: jax.Array, g: jax.Array) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return L.rmsnorm(y, g)


def block_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                h0: Optional[jax.Array] = None,
                conv_tail: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence SSD block.  x (B,S,d) -> (y (B,S,d), h_final,
    conv_tail)."""
    dm = dims(cfg)
    heads, n, pdim = dm["heads"], dm["n"], dm["p"]
    B, S, _ = x.shape
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs = xbc[..., :dm["d_in"]].reshape(B, S, heads, pdim)
    b_in = xbc[..., dm["d_in"]:dm["d_in"] + n]         # (B,S,N)
    c_in = xbc[..., dm["d_in"] + n:]                   # (B,S,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                           # (H,)
    log_decay = dt * a                                  # (B,S,H)
    u = xs.astype(jnp.float32) * dt[..., None]          # dt-scaled input
    bb = jnp.broadcast_to(b_in[:, :, None, :], (B, S, heads, n))
    cc = jnp.broadcast_to(c_in[:, :, None, :], (B, S, heads, n))
    y, h_final = ssd_chunked(u, log_decay, bb, cc, h0=h0,
                             chunk=cfg.ssm.chunk)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, dm["d_in"]).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_g"])
    return y @ p["out_proj"], h_final, new_tail


def block_step(cfg: ModelConfig, p: Params, x: jax.Array,
               h: jax.Array, conv_tail: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode step.  x (B,1,d), h (B,H,N,P),
    conv_tail (B,K-1,conv_dim)."""
    dm = dims(cfg)
    heads, n, pdim = dm["heads"], dm["n"], dm["p"]
    B = x.shape[0]
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs = xbc[:, 0, :dm["d_in"]].reshape(B, heads, pdim)
    b_in = xbc[:, 0, dm["d_in"]:dm["d_in"] + n]
    c_in = xbc[:, 0, dm["d_in"] + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    log_decay = dt * a                                  # (B,H)
    u = xs.astype(jnp.float32) * dt[..., None]
    bb = jnp.broadcast_to(b_in[:, None, :], (B, heads, n))
    cc = jnp.broadcast_to(c_in[:, None, :], (B, heads, n))
    y, h_new = ssd_step(u, log_decay, bb, cc, h)
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, dm["d_in"]).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_g"])
    return y @ p["out_proj"], h_new, new_tail


def state_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Per-request state entry shapes for the Guardian state pool."""
    dm = dims(cfg)
    return {
        "ssm": (dm["heads"], dm["n"], dm["p"]),
        "conv": (cfg.ssm.conv_width - 1, dm["conv_dim"]),
    }
