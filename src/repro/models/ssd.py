"""Chunked SSD (state-space dual) scan — the Mamba2 primitive, shared by
the zamba2 Mamba2 blocks and the xLSTM mLSTM blocks (which are the same
recurrence with scalar gates).

Recurrence (per batch, head):

    h_t = exp(a_t) * h_{t-1} + b_t ⊗ u_t          h: (N, P) state
    y_t = c_t · h_t                                y: (P,)

with a_t scalar log-decay, b_t,c_t: (N,), u_t: (P,).  The chunked algorithm
(Mamba2 §6) splits S into chunks of length Q: intra-chunk contributions via
a (Q,Q) masked decay matrix, inter-chunk via a short scan over chunk states
— O(S·Q) work instead of O(S²), parallel over (batch, heads, chunks).

Everything is f32 internally (decays are exp-of-sums; bf16 under/overflows).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) per-step log decays -> (..., Q, Q) lower-tri matrix
    L[t, s] = sum_{s < r <= t} a[r]   (t >= s), -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    u: jax.Array,        # (B, S, H, P) inputs (already dt-scaled)
    a: jax.Array,        # (B, S, H) log decays (dt * A, or log f)
    b: jax.Array,        # (B, S, H, N) input maps (dt folded upstream)
    c: jax.Array,        # (B, S, H, N) output maps
    h0: Optional[jax.Array] = None,   # (B, H, N, P) initial state
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    B, S, H, P = u.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    u = u.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    a = a.reshape(B, nc, chunk, H).astype(jnp.float32)
    b = b.reshape(B, nc, chunk, H, N).astype(jnp.float32)
    c = c.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    a_hq = jnp.moveaxis(a, -1, -2)                    # (B,nc,H,Q)
    L = jnp.exp(segsum(a_hq))                         # (B,nc,H,Q,Q)

    # intra-chunk: y[t] = sum_{s<=t} (c_t·b_s) L[t,s] u_s
    y_intra = jnp.einsum("bcqhn,bcshn,bchqs,bcshp->bcqhp", c, b, L, u)

    # chunk states: h_c = sum_s exp(A_end - A_s) b_s ⊗ u_s
    cs = jnp.cumsum(a_hq, axis=-1)                    # (B,nc,H,Q)
    decay_to_end = jnp.exp(cs[..., -1:] - cs)         # (B,nc,H,Q)
    d2e = jnp.moveaxis(decay_to_end, -1, -2)          # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchnp", b, d2e, u)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cs[..., -1])                # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(h_prev, inp):
        dec, st = inp                                  # (B,H), (B,H,N,P)
        h_new = dec[..., None, None] * h_prev + st
        return h_new, h_prev                           # emit state BEFORE

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)            # (nc,B,H)
    st_t = jnp.moveaxis(states, 1, 0)                  # (nc,B,H,N,P)
    h_final, prev_states = jax.lax.scan(step, h0, (dec_t, st_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B,nc,H,N,P)

    # inter-chunk: y[t] += (c_t · H_{c-1}) * exp(A_t)
    state_decay_in = jnp.exp(jnp.moveaxis(cs, -1, -2))  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp",
                         c, state_decay_in, prev_states)

    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y, h_final


def ssd_step(
    u: jax.Array,        # (B, H, P)
    a: jax.Array,        # (B, H) log decay
    b: jax.Array,        # (B, H, N)
    c: jax.Array,        # (B, H, N)
    h: jax.Array,        # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent step (decode).  Returns (y (B,H,P), h')."""
    h = h.astype(jnp.float32)
    h_new = (jnp.exp(a.astype(jnp.float32))[..., None, None] * h
             + jnp.einsum("bhn,bhp->bhnp", b.astype(jnp.float32),
                          u.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhnp->bhp", c.astype(jnp.float32), h_new)
    return y, h_new


def ssd_reference(u, a, b, c, h0=None):
    """O(S) sequential oracle for tests: identical semantics to
    ssd_chunked, computed step by step."""
    B, S, H, P = u.shape
    N = b.shape[-1]
    h = (jnp.zeros((B, H, N, P), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(S):
        y, h = ssd_step(u[:, t], a[:, t], b[:, t], c[:, t], h)
        ys.append(y)
    return jnp.stack(ys, axis=1), h
