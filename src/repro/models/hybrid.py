"""zamba2-7b — hybrid backbone: Mamba2 (SSD) layers with a *shared*
full-attention+MLP block invoked after every ``attn_every`` SSD layers.

Layer layout for n_layers=81, attn_every=6:

    [6 mamba] attn* [6 mamba] attn* ... (13 groups) ... [3 mamba tail]

where ``attn*`` is the same parameter block every time (zamba2's weight
sharing).  Windowed attention (cfg.attn_window) keeps the arch
sub-quadratic, so long_500k runs: SSM state is O(1) per token and the
shared-attention KV is capped at the window.

Serving state per request: 13 windowed-KV slabs (one per attn invocation)
+ per-mamba-layer SSM/conv state in the Guardian state pool.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models import mamba2 as M2
from repro.models.guard import GuardSpec

Params = Dict[str, Any]


def group_structure(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups of attn_every mamba layers each followed by shared attn,
    n_tail mamba layers)."""
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.n_layers - g * cfg.attn_every


def n_attn_calls(cfg: ModelConfig) -> int:
    return group_structure(cfg)[0]


def init(rng, cfg: ModelConfig) -> Params:
    k_emb, k_m, k_a, k_mlp = jax.random.split(rng, 4)
    g, tail = group_structure(cfg)
    mkeys = jax.random.split(k_m, cfg.n_layers)
    grouped = jax.vmap(lambda k: M2.block_init(k, cfg))(
        mkeys[:g * cfg.attn_every])
    grouped = jax.tree.map(
        lambda x: x.reshape(g, cfg.attn_every, *x.shape[1:]), grouped)
    tail_p = (jax.vmap(lambda k: M2.block_init(k, cfg))(
        mkeys[g * cfg.attn_every:]) if tail else None)
    p: Params = {
        "embed": L.embedding_init(k_emb, cfg),
        "mamba": grouped,
        "shared_attn": {
            "attn": L.attention_init(k_a, cfg),
            "mlp": L.mlp_init(k_mlp, cfg),
            "norm1": L.norm_init(cfg),
            "norm2": L.norm_init(cfg),
        },
        "norm_f": L.norm_init(cfg),
    }
    if tail:
        p["mamba_tail"] = tail_p
    return p


def param_logical_axes(cfg: ModelConfig) -> Params:
    g, tail = group_structure(cfg)

    def stack2(tree):
        return jax.tree.map(lambda axes: (None, None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    def stack1(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    p: Params = {
        "embed": L.embedding_axes(cfg),
        "mamba": stack2(M2.block_axes(cfg)),
        "shared_attn": {
            "attn": L.attention_axes(cfg),
            "mlp": L.mlp_axes(cfg),
            "norm1": L.norm_axes(cfg),
            "norm2": L.norm_axes(cfg),
        },
        "norm_f": L.norm_axes(cfg),
    }
    if tail:
        p["mamba_tail"] = stack1(M2.block_axes(cfg))
    return p


# ---------------------------------------------------------------------------
# Training / full-sequence forward
# ---------------------------------------------------------------------------

def _shared_attn_full(cfg, p, x, positions, rules=None):
    sa = p["shared_attn"]
    q, k, v = L.qkv_proj(cfg, sa["attn"], L.apply_norm(cfg, sa["norm1"], x))
    q, k = L.positions_rope(cfg, q, k, positions)
    o = L.chunked_attention(q, k, v, causal=True, window=cfg.attn_window, rules=rules)
    x = x + L.out_proj(cfg, sa["attn"], o)
    x = x + L.mlp_apply(cfg, sa["mlp"], L.apply_norm(cfg, sa["norm2"], x))
    return x


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions: Optional[jax.Array] = None, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = False) -> jax.Array:
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def mamba_group(x, group_p):
        def one(x, p):
            y, _, _ = M2.block_apply(cfg, p, x)
            x = x + y
            if rules is not None:
                x = constrain(x, rules, ("batch", "seq", None))
            return x, None
        x, _ = jax.lax.scan(one, x, group_p)
        return x

    def group_body(x, group_p):
        x = mamba_group(x, group_p)
        x = _shared_attn_full(cfg, params, x, positions, rules)
        return x, None

    body = group_body
    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["mamba"])
    if "mamba_tail" in params:
        def one_t(x, p):
            y, _, _ = M2.block_apply(cfg, p, x)
            return x + y, None
        x, _ = jax.lax.scan(one_t, x, params["mamba_tail"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    return L.lm_logits(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> jax.Array:
    tokens = batch["tokens"]
    logits = forward(cfg, params, tokens[:, :-1], guard=guard, rules=rules,
                     remat=remat)
    return L.softmax_cross_entropy(logits, tokens[:, 1:],
                                   batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving — hybrid cache: windowed-KV slabs + SSM state pool
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridCache:
    kv: KV.PagedKVCache          # n_attn_calls layers, windowed
    state: KV.StateCache         # per-mamba-layer ssm + conv state


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, slots=None) -> HybridCache:
    g, tail = group_structure(cfg)
    window = cfg.attn_window or max_len
    kv = KV.init_kv_cache(cfg, batch, min(max_len, window), dtype=dtype,
                          n_layers=g, slots=slots)
    shapes = M2.state_shapes(cfg)
    if slots is None:
        slots = max(1 << (batch - 1).bit_length(), 1) if batch > 1 else 1
    pools = {
        "ssm": jnp.zeros((cfg.n_layers, slots, *shapes["ssm"]),
                         jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, slots, *shapes["conv"]),
                          dtype),
    }
    state = KV.StateCache(pools=pools,
                          slot_ids=jnp.arange(batch, dtype=jnp.int32),
                          seq_lens=jnp.zeros((batch,), jnp.int32))
    return HybridCache(kv=kv, state=state)


def prefill(cfg: ModelConfig, params: Params, cache: HybridCache,
            tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            positions: Optional[jax.Array] = None
            ) -> Tuple[HybridCache, jax.Array]:
    """Process the prompt: run SSD blocks full-sequence capturing final
    states; write the last `window` tokens' KV for each shared-attention
    invocation; return last-position logits."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    g, tail = group_structure(cfg)
    state = cache.state
    kvc = cache.kv
    window = kvc.max_len

    def mamba_full(x, st, lidx, p):
        y, h_f, tail_c = M2.block_apply(cfg, p, x)
        st = st.write("ssm", lidx, h_f, guard)
        st = st.write("conv", lidx,
                      tail_c[:, -(cfg.ssm.conv_width - 1):].astype(
                          st.pools["conv"].dtype), guard)
        return x + y, st

    def group_body(carry, inp):
        x, st, kc, vc = carry
        gi, group_p = inp

        def m_body(c, inp2):
            x, st = c
            li, p = inp2
            x, st = mamba_full(x, st, gi * cfg.attn_every + li, p)
            return (x, st), None
        (x, st), _ = jax.lax.scan(
            m_body, (x, st),
            (jnp.arange(cfg.attn_every, dtype=jnp.int32), group_p))
        sa = params["shared_attn"]
        q, k, v = L.qkv_proj(cfg, sa["attn"],
                             L.apply_norm(cfg, sa["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        o = L.chunked_attention(q, k, v, causal=True,
                                window=cfg.attn_window, rules=rules)
        x = x + L.out_proj(cfg, sa["attn"], o)
        x = x + L.mlp_apply(cfg, sa["mlp"],
                            L.apply_norm(cfg, sa["norm2"], x))
        # stash the trailing window of KV for decode
        kw = k[:, -window:] if S >= window else k
        vw = v[:, -window:] if S >= window else v
        pad = window - kw.shape[1]
        if pad > 0:
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tmp = dataclasses.replace(kvc, k=kc, v=vc)
        tmp = KV.write_prefill_kv(tmp, gi, kw.astype(kc.dtype),
                                  vw.astype(vc.dtype), guard)
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return (x, st, tmp.k, tmp.v), None

    (x, state, kc, vc), _ = jax.lax.scan(
        group_body, (x, state, kvc.k, kvc.v),
        (jnp.arange(g, dtype=jnp.int32), params["mamba"]))
    kvc = dataclasses.replace(kvc, k=kc, v=vc,
                              seq_lens=jnp.minimum(kvc.seq_lens + S,
                                                   window))
    if "mamba_tail" in params:
        def t_body(c, inp2):
            x, st = c
            li, p = inp2
            x, st = mamba_full(x, st, g * cfg.attn_every + li, p)
            return (x, st), None
        (x, state), _ = jax.lax.scan(
            t_body, (x, state),
            (jnp.arange(tail, dtype=jnp.int32), params["mamba_tail"]))
    state = dataclasses.replace(state, seq_lens=state.seq_lens + S)
    x = L.apply_norm(cfg, params["norm_f"], x[:, -1:])
    logits = L.lm_logits(cfg, params["embed"], x)
    return HybridCache(kv=kvc, state=state), logits[:, 0]


def _decode_shared_attn(cfg, params, cache: KV.PagedKVCache, x, lidx,
                        positions, guard, rules=None):
    sa = params["shared_attn"]
    q, k, v = L.qkv_proj(cfg, sa["attn"], L.apply_norm(cfg, sa["norm1"], x))
    q, k = L.positions_rope(cfg, q, k, positions)
    # windowed cache: write position wraps modulo the window
    wrapped = dataclasses.replace(
        cache, seq_lens=jnp.minimum(cache.seq_lens, cache.max_len - 1))
    wrapped = KV.append_token_kv(wrapped, lidx, k.astype(cache.k.dtype),
                                 v.astype(cache.v.dtype), guard)
    cache = dataclasses.replace(cache, k=wrapped.k, v=wrapped.v)
    k_hist, v_hist = KV.gather_layer_kv(cache, lidx, guard, rules)
    kv_len = jnp.minimum(cache.seq_lens + 1,
                         jnp.int32(cache.max_len))
    o = L.decode_attention(q, k_hist.astype(q.dtype),
                           v_hist.astype(q.dtype), kv_len,
                           window=cfg.attn_window)
    x = x + L.out_proj(cfg, sa["attn"], o)
    x = x + L.mlp_apply(cfg, sa["mlp"], L.apply_norm(cfg, sa["norm2"], x))
    return cache, x


def decode(cfg: ModelConfig, params: Params, cache: HybridCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None
           ) -> Tuple[HybridCache, jax.Array]:
    """One decode step through the hybrid stack."""
    B = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens[:, None], guard)
    if positions is None:
        positions = cache.state.seq_lens[:, None]
    g, tail = group_structure(cfg)
    state = cache.state
    kvc = cache.kv

    def mamba_one(x, state, lidx, p):
        h = state.read("ssm", lidx, guard)
        tail_c = state.read("conv", lidx, guard)
        y, h_new, tail_new = M2.block_step(cfg, p, x, h, tail_c)
        state = state.write("ssm", lidx, h_new, guard)
        state = state.write("conv", lidx, tail_new.astype(
            state.pools["conv"].dtype), guard)
        return x + y, state

    def group_body(carry, inp):
        x, st, kc, vc = carry
        gi, group_p = inp

        def m_body(c, inp2):
            x, st = c
            li, p = inp2
            x, st = mamba_one(x, st, gi * cfg.attn_every + li, p)
            return (x, st), None
        (x, st), _ = jax.lax.scan(
            m_body, (x, st),
            (jnp.arange(cfg.attn_every, dtype=jnp.int32), group_p))
        tmp = dataclasses.replace(kvc, k=kc, v=vc)
        tmp, x = _decode_shared_attn(cfg, params, tmp, x, gi, positions,
                                     guard, rules)
        return (x, st, tmp.k, tmp.v), None

    (x, state, kc, vc), _ = jax.lax.scan(
        group_body, (x, state, kvc.k, kvc.v),
        (jnp.arange(g, dtype=jnp.int32), params["mamba"]))
    kvc = dataclasses.replace(kvc, k=kc, v=vc, seq_lens=kvc.seq_lens + 1)
    if "mamba_tail" in params:
        def t_body(c, inp2):
            x, st = c
            li, p = inp2
            x, st = mamba_one(x, st, g * cfg.attn_every + li, p)
            return (x, st), None
        (x, state), _ = jax.lax.scan(
            t_body, (x, state),
            (jnp.arange(tail, dtype=jnp.int32), params["mamba_tail"]))
    state = dataclasses.replace(state, seq_lens=state.seq_lens + 1)
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return HybridCache(kv=kvc, state=state), logits[:, 0]
