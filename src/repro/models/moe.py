"""MoE decoder LM — grok-1-314b (8e top-2) / qwen3-moe-30b-a3b (128e top-8).

Expert parallelism: expert tensors carry an ``expert`` logical axis mapped
to the mesh ``model`` axis; dispatch uses the capacity-based one-hot einsum
formulation so GSPMD inserts the all-to-alls.  The **router's expert ids are
data-dependent** — the Guardian "expert" fence is applied to them before
they form dispatch offsets, so a corrupted/adversarial router can never
address another tenant's expert-buffer rows (the MoE analogue of the
paper's fenced ld/st).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models import transformer as TF
from repro.models.guard import GuardSpec, fence

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Expert MLP bank + router
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    kr, kg, ku, kd = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    out_std = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    dt = L.dtype_of(cfg)
    p: Params = {
        "router": (jax.random.normal(kr, (d, e), jnp.float32) * std
                   ).astype(jnp.float32),
        "wu": (jax.random.normal(ku, (e, d, f), jnp.float32) * std
               ).astype(dt),
        "wd": (jax.random.normal(kd, (e, f, d), jnp.float32) * out_std
               ).astype(dt),
    }
    if cfg.act == "silu":
        p["wg"] = (jax.random.normal(kg, (e, d, f), jnp.float32) * std
                   ).astype(dt)
    return p


def moe_axes(cfg: ModelConfig) -> Params:
    p: Params = {
        "router": (None, None),
        "wu": ("expert", "embed", None),
        "wd": ("expert", None, "embed"),
    }
    if cfg.act == "silu":
        p["wg"] = ("expert", "embed", None)
    return p


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array,
              guard: Optional[GuardSpec] = None,
              rules: Optional[ShardingRules] = None,
              dispatch: str = "scatter",
              capacity_factor: float = 2.0) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar).

    Capacity-based dispatch: top-k expert choice per token, tokens routed
    into per-expert buffers of capacity C = ceil(2*K*T/E); overflow drops
    (standard).  All shapes static => dry-run friendly.

    Two dispatch implementations (§Perf hillclimb H1):

    * ``einsum``  — Mesh-TF one-hot dispatch tensors (T,E,C).  Simple, but
      the dispatch/combine einsums cost O(T·E·C·d) FLOPs — they dominate
      the step for fine-grained MoE (qwen3: 128e top-8).
    * ``scatter`` — fenced destination indices ``dest = e·C + pos`` with a
      scatter into the (E·C, d) buffer and a gather back: O(T·K·d) data
      movement, zero dispatch FLOPs.  The fence on ``dest`` is exactly the
      paper's store fence (a corrupted route wraps inside the tenant's
      expert-buffer partition).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (T,K)
    # Guardian: expert ids are data-dependent addresses into the expert
    # bank — fence them into the tenant's expert partition.
    expert_ids = fence(guard, "expert", expert_ids.astype(jnp.int32))
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce)

    capacity = max(int(capacity_factor * K * T / E), 8)
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)   # (T,K,E)
    pos_in_e = (jnp.cumsum(onehot.reshape(T * K, E), axis=0) - 1.0
                ).reshape(T, K, E)                              # rank
    pos = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)  # (T,K)
    keep = (pos < capacity)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    if dispatch == "einsum":
        # dispatch tensor (T, E, C) — one-hot on (expert, slot)
        slot_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                                 capacity, dtype=xf.dtype)      # (T,K,C)
        disp = jnp.einsum("tke,tkc->tec", onehot.astype(xf.dtype),
                          slot_oh)
        xin = jnp.einsum("td,tec->ecd", xf, disp)               # (E,C,d)
    else:
        # fenced scatter dispatch: dest in [0, E*C) by construction
        # (expert_ids fenced + pos < capacity); dropped rows -> E*C.
        # Sharding: src rows are token-major (data axes); buf rows are
        # expert-major (model axis) — the scatter across them is the
        # dispatch all-to-all.  Without these constraints GSPMD
        # replicates the (E*C, d) buffer on every chip (§Perf H1 iter2).
        dest = (expert_ids * capacity + jnp.minimum(
            pos, capacity - 1)).reshape(T * K)                  # (T*K,)
        dest = jnp.where(keep.reshape(T * K), dest, E * capacity)
        src = jnp.broadcast_to(xf[:, None, :], (T, K, d)).reshape(
            T * K, d)
        if rules is not None:
            src = constrain(src, rules, ("batch", None))
        buf = jnp.zeros((E * capacity + 1, d), xf.dtype)
        if rules is not None:
            buf = constrain(buf, rules, ("expert", None))
        buf = buf.at[dest].set(src, mode="drop")
        if rules is not None:
            buf = constrain(buf, rules, ("expert", None))
        xin = buf[:E * capacity].reshape(E, capacity, d)
    if rules is not None:
        xin = constrain(xin, rules, ("expert", None, None))

    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xin, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin, p["wu"]))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wd"])               # (E,C,d)
    if rules is not None:
        out_e = constrain(out_e, rules, ("expert", None, None))

    if dispatch == "einsum":
        combine = jnp.einsum("tec,tke,tk->tec", disp,
                             onehot.astype(xf.dtype),
                             gate_vals.astype(xf.dtype))
        out = jnp.einsum("tec,ecd->td", combine, out_e)
    else:
        flat = out_e.reshape(E * capacity, d)
        if rules is not None:
            flat = constrain(flat, rules, ("expert", None))
        y_tk = jnp.take(flat, jnp.minimum(dest, E * capacity - 1),
                        axis=0).reshape(T, K, d)
        if rules is not None:
            y_tk = constrain(y_tk, rules, ("batch", None, None))
        w = (gate_vals * keep.astype(gate_vals.dtype)).astype(xf.dtype)
        out = jnp.einsum("tkd,tk->td", y_tk, w)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Model (attention blocks reuse the dense transformer pieces)
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attention_init(k1, cfg),
        "moe": moe_init(k2, cfg),
        "norm1": L.norm_init(cfg),
        "norm2": L.norm_init(cfg),
    }


def init(rng, cfg: ModelConfig) -> Params:
    k_emb, k_layers = jax.random.split(rng)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": L.embedding_init(k_emb, cfg),
        "layers": stacked,
        "norm_f": L.norm_init(cfg),
    }


def param_logical_axes(cfg: ModelConfig) -> Params:
    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embedding_axes(cfg),
        "layers": stack({
            "attn": L.attention_axes(cfg),
            "moe": moe_axes(cfg),
            "norm1": L.norm_axes(cfg),
            "norm2": L.norm_axes(cfg),
        }),
        "norm_f": L.norm_axes(cfg),
    }


def _layer(cfg, rules, guard, p, x, positions, aux_acc, dispatch="scatter"):
    q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
    q, k = L.positions_rope(cfg, q, k, positions)
    o = L.chunked_attention(q, k, v, causal=True, window=cfg.attn_window, rules=rules)
    x = x + L.out_proj(cfg, p["attn"], o)
    h, aux = moe_apply(cfg, p["moe"], L.apply_norm(cfg, p["norm2"], x),
                       guard, rules, dispatch)
    x = x + h
    if rules is not None:
        x = constrain(x, rules, ("batch", "seq", None))
    return x, aux_acc + aux


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions: Optional[jax.Array] = None, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = False,
            dispatch: str = "scatter",
            remat_policy: str = "nothing") -> Tuple[jax.Array, jax.Array]:
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def body(carry, p):
        x, aux = carry
        x, aux = _layer(cfg, rules, guard, p, x, positions, aux,
                        dispatch)
        return (x, aux), None

    step = body
    if remat:
        pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
               if remat_policy == "dots"
               else jax.checkpoint_policies.nothing_saveable)
        step = jax.checkpoint(body, policy=pol)
    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)),
                               params["layers"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, aux / cfg.n_layers


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True, aux_weight: float = 0.01,
            dispatch: str = "scatter",
            remat_policy: str = "nothing") -> jax.Array:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = forward(cfg, params, inputs, guard=guard, rules=rules,
                          remat=remat, dispatch=dispatch,
                          remat_policy=remat_policy)
    return (L.softmax_cross_entropy(logits, labels, batch.get("mask"))
            + aux_weight * aux)


# ---------------------------------------------------------------------------
# Serving — same cache discipline as the dense model
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
            tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            positions: Optional[jax.Array] = None,
            dispatch: str = "scatter",
            ) -> Tuple[KV.PagedKVCache, jax.Array]:
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def body(carry, inp):
        x, kc, vc = carry
        p, lidx = inp
        q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache, k=kc, v=vc)
        tmp = KV.write_prefill_kv(tmp, lidx, k.astype(kc.dtype),
                                  v.astype(vc.dtype), guard)
        o = L.chunked_attention(q, k, v, causal=True,
                                window=cfg.attn_window, rules=rules)
        x = x + L.out_proj(cfg, p["attn"], o)
        h, _ = moe_apply(cfg, p["moe"], L.apply_norm(cfg, p["norm2"], x),
                         guard, rules, dispatch)
        x = x + h
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return (x, tmp.k, tmp.v), None

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc), _ = jax.lax.scan(body, (x, cache.k, cache.v),
                                  (params["layers"], lidxs))
    cache = dataclasses.replace(cache, k=kc, v=vc,
                                seq_lens=cache.seq_lens + S)
    x = L.apply_norm(cfg, params["norm_f"], x[:, -1:])
    return cache, L.lm_logits(cfg, params["embed"], x)[:, 0]


def decode(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None,
           dispatch: str = "scatter"
           ) -> Tuple[KV.PagedKVCache, jax.Array]:
    B = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens[:, None], guard)
    if positions is None:
        positions = cache.seq_lens[:, None]
    elif positions.ndim == 1:
        positions = positions[:, None]

    def body(carry, inp):
        x, kc, vc = carry
        p, lidx = inp
        q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache, k=kc, v=vc)
        tmp = KV.append_token_kv(tmp, lidx, k.astype(kc.dtype),
                                 v.astype(vc.dtype), guard)
        k_hist, v_hist = KV.gather_layer_kv(tmp, lidx, guard, rules)
        o = L.decode_attention(q, k_hist.astype(q.dtype),
                               v_hist.astype(q.dtype),
                               cache.seq_lens + 1,
                               window=cfg.attn_window)
        x = x + L.out_proj(cfg, p["attn"], o)
        h, _ = moe_apply(cfg, p["moe"], L.apply_norm(cfg, p["norm2"], x),
                         guard, rules, dispatch)
        x = x + h
        return (x, tmp.k, tmp.v), None

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc), _ = jax.lax.scan(body, (x, cache.k, cache.v),
                                  (params["layers"], lidxs))
    cache = dataclasses.replace(cache, k=kc, v=vc,
                                seq_lens=cache.seq_lens + 1)
    x = L.apply_norm(cfg, params["norm_f"], x)
    return cache, L.lm_logits(cfg, params["embed"], x)[:, 0]
