"""Encoder-decoder backbone — seamless-m4t-medium.

The audio frontend is a stub per assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d).  Decoder = causal self-attn
(paged-slab KV, fenced) + cross-attn to the encoder memory (computed once
per request, stored per slot in the pool — slot ids fenced).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models.guard import GuardSpec, fence

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attention_init(k1, cfg),
        "mlp": L.mlp_init(k2, cfg),
        "norm1": L.norm_init(cfg),
        "norm2": L.norm_init(cfg),
    }


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn": L.attention_init(k1, cfg),
        "cross": L.attention_init(k2, cfg),
        "mlp": L.mlp_init(k3, cfg),
        "norm1": L.norm_init(cfg),
        "norm_x": L.norm_init(cfg),
        "norm2": L.norm_init(cfg),
    }


def init(rng, cfg: ModelConfig) -> Params:
    k_emb, k_enc, k_dec = jax.random.split(rng, 3)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(k_enc, cfg.enc_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(k_dec, cfg.n_layers))
    return {
        "embed": L.embedding_init(k_emb, cfg),
        "enc": enc,
        "dec": dec,
        "norm_enc": L.norm_init(cfg),
        "norm_f": L.norm_init(cfg),
    }


def param_logical_axes(cfg: ModelConfig) -> Params:
    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embedding_axes(cfg),
        "enc": stack({
            "attn": L.attention_axes(cfg), "mlp": L.mlp_axes(cfg),
            "norm1": L.norm_axes(cfg), "norm2": L.norm_axes(cfg)}),
        "dec": stack({
            "attn": L.attention_axes(cfg), "cross": L.attention_axes(cfg),
            "mlp": L.mlp_axes(cfg), "norm1": L.norm_axes(cfg),
            "norm_x": L.norm_axes(cfg), "norm2": L.norm_axes(cfg)}),
        "norm_enc": L.norm_axes(cfg),
        "norm_f": L.norm_axes(cfg),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params, src: jax.Array,
           rules: Optional[ShardingRules] = None,
           remat: bool = False) -> jax.Array:
    """src: precomputed frame embeddings (B, S_src, d) -> memory."""
    B, S, _ = src.shape
    positions = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = src

    def layer(x, p):
        q, k, v = L.qkv_proj(cfg, p["attn"],
                             L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        o = L.chunked_attention(q, k, v, causal=False, rules=rules)
        x = x + L.out_proj(cfg, p["attn"], o)
        x = x + L.mlp_apply(cfg, p["mlp"],
                            L.apply_norm(cfg, p["norm2"], x))
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return x, None

    body = layer
    if remat:
        body = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.apply_norm(cfg, params["norm_enc"], x)


# ---------------------------------------------------------------------------
# Decoder (training / scoring)
# ---------------------------------------------------------------------------

def _cross_attn(cfg, p, x, memory, rules=None):
    """Cross attention: queries from x, keys/values from encoder memory."""
    B, S, _ = x.shape
    xn = L.apply_norm(cfg, p["norm_x"], x)
    q = (xn @ p["cross"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (memory @ p["cross"]["wk"]).reshape(
        B, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ p["cross"]["wv"]).reshape(
        B, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
    o = L.chunked_attention(q, k, v, causal=False, rules=rules)
    return L.out_proj(cfg, p["cross"], o)


def decode_train(cfg: ModelConfig, params: Params, tgt: jax.Array,
                 memory: jax.Array, *, guard: Optional[GuardSpec] = None,
                 rules: Optional[ShardingRules] = None,
                 remat: bool = False) -> jax.Array:
    B, S = tgt.shape
    positions = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = L.embed_tokens(params["embed"], tgt, guard)

    def layer(x, p):
        q, k, v = L.qkv_proj(cfg, p["attn"],
                             L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        o = L.chunked_attention(q, k, v, causal=True, rules=rules)
        x = x + L.out_proj(cfg, p["attn"], o)
        x = x + _cross_attn(cfg, p, x, memory, rules)
        x = x + L.mlp_apply(cfg, p["mlp"],
                            L.apply_norm(cfg, p["norm2"], x))
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return x, None

    body = layer
    if remat:
        body = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    return L.lm_logits(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> jax.Array:
    memory = encode(cfg, params, batch["src"], rules, remat)
    tgt = batch["tgt"]
    logits = decode_train(cfg, params, tgt[:, :-1], memory, guard=guard,
                          rules=rules, remat=remat)
    return L.softmax_cross_entropy(logits, tgt[:, 1:], batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving — decoder KV slabs + per-slot cross-attention memory pool
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncDecCache:
    kv: KV.PagedKVCache            # decoder self-attention
    cross_k: jax.Array             # (L, slots, S_src, KH, D)
    cross_v: jax.Array
    src_lens: jax.Array            # (B,)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int,
               dtype=jnp.bfloat16) -> EncDecCache:
    kv = KV.init_kv_cache(cfg, batch, max_len, dtype=dtype)
    slots = kv.k.shape[1]
    shape = (cfg.n_layers, slots, src_len, cfg.n_kv_heads, cfg.head_dim)
    return EncDecCache(
        kv=kv,
        cross_k=jnp.zeros(shape, dtype),
        cross_v=jnp.zeros(shape, dtype),
        src_lens=jnp.zeros((batch,), jnp.int32),
    )


def prefill(cfg: ModelConfig, params: Params, cache: EncDecCache,
            src: jax.Array, tgt: jax.Array, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None
            ) -> Tuple[EncDecCache, jax.Array]:
    """Encode src, precompute per-layer cross KV, prefill decoder slabs."""
    memory = encode(cfg, params, src, rules)
    B, S_src, _ = memory.shape
    B2, S = tgt.shape
    positions = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B2, S))
    x = L.embed_tokens(params["embed"], tgt, guard)
    slots = fence(guard, "kv", cache.kv.slot_ids)

    def body(carry, inp):
        x, kc, vc, xk, xv = carry
        p, lidx = inp
        # self-attention with slab write
        q, k, v = L.qkv_proj(cfg, p["attn"],
                             L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache.kv, k=kc, v=vc)
        tmp = KV.write_prefill_kv(tmp, lidx, k.astype(kc.dtype),
                                  v.astype(vc.dtype), guard)
        o = L.chunked_attention(q, k, v, causal=True, rules=rules)
        x = x + L.out_proj(cfg, p["attn"], o)
        # cross attention + stash cross KV for decode
        ck = (memory @ p["cross"]["wk"]).reshape(
            B, S_src, cfg.n_kv_heads, cfg.head_dim)
        cv = (memory @ p["cross"]["wv"]).reshape(
            B, S_src, cfg.n_kv_heads, cfg.head_dim)
        xk = xk.at[lidx, slots].set(ck.astype(xk.dtype),
                                    mode="promise_in_bounds")
        xv = xv.at[lidx, slots].set(cv.astype(xv.dtype),
                                    mode="promise_in_bounds")
        xn = L.apply_norm(cfg, p["norm_x"], x)
        qx = (xn @ p["cross"]["wq"]).reshape(
            B2, S, cfg.n_heads, cfg.head_dim)
        ox = L.chunked_attention(qx, ck, cv, causal=False, rules=rules)
        x = x + L.out_proj(cfg, p["cross"], ox)
        x = x + L.mlp_apply(cfg, p["mlp"],
                            L.apply_norm(cfg, p["norm2"], x))
        return (x, tmp.k, tmp.v, xk, xv), None

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc, xk, xv), _ = jax.lax.scan(
        body, (x, cache.kv.k, cache.kv.v, cache.cross_k, cache.cross_v),
        (params["dec"], lidxs))
    kv = dataclasses.replace(cache.kv, k=kc, v=vc,
                             seq_lens=cache.kv.seq_lens + S)
    cache = EncDecCache(kv=kv, cross_k=xk, cross_v=xv,
                        src_lens=jnp.full((B,), S_src, jnp.int32))
    x = L.apply_norm(cfg, params["norm_f"], x[:, -1:])
    return cache, L.lm_logits(cfg, params["embed"], x)[:, 0]


def decode(cfg: ModelConfig, params: Params, cache: EncDecCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None
           ) -> Tuple[EncDecCache, jax.Array]:
    B = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens[:, None], guard)
    if positions is None:
        positions = cache.kv.seq_lens[:, None]
    slots = fence(guard, "kv", cache.kv.slot_ids)

    def body(carry, inp):
        x, kc, vc = carry
        p, lidx = inp
        q, k, v = L.qkv_proj(cfg, p["attn"],
                             L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache.kv, k=kc, v=vc)
        tmp = KV.append_token_kv(tmp, lidx, k.astype(kc.dtype),
                                 v.astype(vc.dtype), guard)
        k_hist, v_hist = KV.gather_layer_kv(tmp, lidx, guard, rules)
        o = L.decode_attention(q, k_hist.astype(q.dtype),
                               v_hist.astype(q.dtype),
                               cache.kv.seq_lens + 1)
        x = x + L.out_proj(cfg, p["attn"], o)
        # cross attention against the cached memory KV
        xn = L.apply_norm(cfg, p["norm_x"], x)
        qx = (xn @ p["cross"]["wq"]).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        ck = jnp.take(xk_l(lidx, cache.cross_k), slots, axis=0)
        cv = jnp.take(xk_l(lidx, cache.cross_v), slots, axis=0)
        ox = L.decode_attention(qx, ck.astype(qx.dtype),
                                cv.astype(qx.dtype), cache.src_lens)
        x = x + L.out_proj(cfg, p["cross"], ox)
        x = x + L.mlp_apply(cfg, p["mlp"],
                            L.apply_norm(cfg, p["norm2"], x))
        return (x, tmp.k, tmp.v), None

    def xk_l(lidx, pool):
        return jax.lax.dynamic_index_in_dim(pool, lidx, axis=0,
                                            keepdims=False)

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc), _ = jax.lax.scan(body, (x, cache.kv.k, cache.kv.v),
                                  (params["dec"], lidxs))
    kv = dataclasses.replace(cache.kv, k=kc, v=vc,
                             seq_lens=cache.kv.seq_lens + 1)
    cache = dataclasses.replace(cache, kv=kv)
    x = L.apply_norm(cfg, params["norm_f"], x)
    return cache, L.lm_logits(cfg, params["embed"], x)[:, 0]
