"""Unified model API — one surface over all six families.

``get_model(cfg)`` returns a :class:`ModelAPI` with:

    init(rng)                     -> params
    param_logical_axes()          -> logical-axis pytree (matches params)
    loss(params, batch, ...)      -> scalar loss           [train shapes]
    init_cache(batch, max_len)    -> cache pytree          [serve shapes]
    prefill(params, cache, batch) -> (cache, last_logits)
    decode(params, cache, tokens) -> (cache, logits)
    batch_specs(shape)            -> ShapeDtypeStruct dict for the batch

``batch_specs`` is the assignment's ``input_specs()``: weak-type-correct,
shardable stand-ins for every model input, no device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import kvcache as KV
from repro.models import moe as MOE
from repro.models import transformer as TF
from repro.models import vlm as VLM
from repro.models import xlstm as XL

# frontend stubs: source frames / image patches per request
SRC_FRAMES = 1_024       # seamless encoder input length (frame embeddings)
N_PATCHES = 256          # qwen2-vl patches per request


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    param_logical_axes: Callable
    loss: Callable
    init_cache: Callable
    prefill: Callable
    decode: Callable
    batch_specs: Callable


def _tok_spec(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _dense_api(cfg) if fam == "dense" else _vlm_api(cfg)
    if fam == "moe":
        return _moe_api(cfg)
    if fam == "hybrid":
        return _hybrid_api(cfg)
    if fam == "ssm":
        return _ssm_api(cfg)
    if fam == "encdec":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------

def _dense_api(cfg: ModelConfig) -> ModelAPI:
    def batch_specs(shape: ShapeConfig):
        if shape.kind == "train":
            return {"tokens": _tok_spec(shape.global_batch,
                                        shape.seq_len + 1)}
        if shape.kind == "prefill":
            return {"tokens": _tok_spec(shape.global_batch, shape.seq_len)}
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch,),
                                               jnp.int32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: TF.init(rng, cfg),
        param_logical_axes=lambda: TF.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: TF.loss_fn(cfg, params, batch,
                                                    **kw),
        init_cache=lambda batch, max_len, **kw: KV.init_kv_cache(
            cfg, batch, max_len, **kw),
        prefill=lambda params, cache, batch, **kw: TF.prefill(
            cfg, params, cache, batch["tokens"], **kw),
        decode=lambda params, cache, tokens, **kw: TF.decode(
            cfg, params, cache, tokens, **kw),
        batch_specs=batch_specs,
    )


def _moe_api(cfg: ModelConfig) -> ModelAPI:
    dense = _dense_api(cfg)
    return dataclasses.replace(
        dense,
        init=lambda rng: MOE.init(rng, cfg),
        param_logical_axes=lambda: MOE.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: MOE.loss_fn(cfg, params, batch,
                                                     **kw),
        prefill=lambda params, cache, batch, **kw: MOE.prefill(
            cfg, params, cache, batch["tokens"], **kw),
        decode=lambda params, cache, tokens, **kw: MOE.decode(
            cfg, params, cache, tokens, **kw),
    )


def _vlm_api(cfg: ModelConfig) -> ModelAPI:
    def batch_specs(shape: ShapeConfig):
        b = shape.global_batch
        d = cfg.d_model
        if shape.kind == "train":
            return {
                "tokens": _tok_spec(b, shape.seq_len + 1),
                "patches": jax.ShapeDtypeStruct((b, N_PATCHES, d),
                                                jnp.bfloat16),
                "positions": jax.ShapeDtypeStruct((b, shape.seq_len, 3),
                                                  jnp.int32),
            }
        if shape.kind == "prefill":
            return {
                "tokens": _tok_spec(b, shape.seq_len),
                "patches": jax.ShapeDtypeStruct((b, N_PATCHES, d),
                                                jnp.bfloat16),
                "positions": jax.ShapeDtypeStruct((b, shape.seq_len, 3),
                                                  jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: VLM.init(rng, cfg),
        param_logical_axes=lambda: VLM.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: VLM.loss_fn(cfg, params, batch,
                                                     **kw),
        init_cache=lambda batch, max_len, **kw: KV.init_kv_cache(
            cfg, batch, max_len, **kw),
        prefill=lambda params, cache, batch, **kw: VLM.prefill(
            cfg, params, cache, batch["tokens"], batch["patches"],
            batch["positions"], **kw),
        decode=lambda params, cache, tokens, **kw: VLM.decode(
            cfg, params, cache, tokens, **kw),
        batch_specs=batch_specs,
    )


def _hybrid_api(cfg: ModelConfig) -> ModelAPI:
    def batch_specs(shape: ShapeConfig):
        if shape.kind == "train":
            return {"tokens": _tok_spec(shape.global_batch,
                                        shape.seq_len + 1)}
        if shape.kind == "prefill":
            return {"tokens": _tok_spec(shape.global_batch, shape.seq_len)}
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch,),
                                               jnp.int32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: HY.init(rng, cfg),
        param_logical_axes=lambda: HY.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: HY.loss_fn(cfg, params, batch,
                                                    **kw),
        init_cache=lambda batch, max_len, **kw: HY.init_cache(
            cfg, batch, max_len, **kw),
        prefill=lambda params, cache, batch, **kw: HY.prefill(
            cfg, params, cache, batch["tokens"], **kw),
        decode=lambda params, cache, tokens, **kw: HY.decode(
            cfg, params, cache, tokens, **kw),
        batch_specs=batch_specs,
    )


def _ssm_api(cfg: ModelConfig) -> ModelAPI:
    def batch_specs(shape: ShapeConfig):
        if shape.kind == "train":
            return {"tokens": _tok_spec(shape.global_batch,
                                        shape.seq_len + 1)}
        if shape.kind == "prefill":
            return {"tokens": _tok_spec(shape.global_batch, shape.seq_len)}
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch,),
                                               jnp.int32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: XL.init(rng, cfg),
        param_logical_axes=lambda: XL.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: XL.loss_fn(cfg, params, batch,
                                                    **kw),
        init_cache=lambda batch, max_len=None, **kw: XL.init_cache(
            cfg, batch, **kw),
        prefill=lambda params, cache, batch, **kw: XL.prefill(
            cfg, params, cache, batch["tokens"], **kw),
        decode=lambda params, cache, tokens, **kw: XL.decode(
            cfg, params, cache, tokens, **kw),
        batch_specs=batch_specs,
    )


def _encdec_api(cfg: ModelConfig) -> ModelAPI:
    def batch_specs(shape: ShapeConfig):
        b = shape.global_batch
        d = cfg.d_model
        src = jax.ShapeDtypeStruct((b, SRC_FRAMES, d), jnp.bfloat16)
        if shape.kind == "train":
            return {"src": src, "tgt": _tok_spec(b, shape.seq_len + 1)}
        if shape.kind == "prefill":
            return {"src": src, "tgt": _tok_spec(b, shape.seq_len)}
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: ED.init(rng, cfg),
        param_logical_axes=lambda: ED.param_logical_axes(cfg),
        loss=lambda params, batch, **kw: ED.loss_fn(cfg, params, batch,
                                                    **kw),
        init_cache=lambda batch, max_len, src_len=SRC_FRAMES, **kw:
            ED.init_cache(cfg, batch, max_len, src_len, **kw),
        prefill=lambda params, cache, batch, **kw: ED.prefill(
            cfg, params, cache, batch["src"], batch["tgt"], **kw),
        decode=lambda params, cache, tokens, **kw: ED.decode(
            cfg, params, cache, tokens, **kw),
        batch_specs=batch_specs,
    )
