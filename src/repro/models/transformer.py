"""Dense GQA decoder LM — llama3-405b / qwen1.5-32b / minicpm-2b /
stablelm-3b family (and the text backbone of qwen2-vl).

Layout: scan-over-layers with stacked params (compile time O(1) in depth),
chunked flash-style attention, optional sliding window, paged-slab KV cache
for serving, Guardian fencing on every data-dependent index.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as L
from repro.models.guard import GuardSpec
from repro.models import kvcache as KV

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attention_init(k1, cfg),
        "mlp": L.mlp_init(k2, cfg),
        "norm1": L.norm_init(cfg),
        "norm2": L.norm_init(cfg),
    }


def init(rng, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_out = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    return {
        "embed": L.embedding_init(k_emb, cfg),
        "layers": stacked,
        "norm_f": L.norm_init(cfg),
    }


def param_logical_axes(cfg: ModelConfig) -> Params:
    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embedding_axes(cfg),
        "layers": stack({
            "attn": L.attention_axes(cfg),
            "mlp": L.mlp_axes(cfg),
            "norm1": L.norm_axes(cfg),
            "norm2": L.norm_axes(cfg),
        }),
        "norm_f": L.norm_axes(cfg),
    }


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _attn_train(cfg: ModelConfig, p: Params, x, positions,
                rules: Optional[ShardingRules]):
    q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
    q, k = L.positions_rope(cfg, q, k, positions)
    o = L.chunked_attention(q, k, v, causal=True, window=cfg.attn_window, rules=rules)
    return L.out_proj(cfg, p["attn"], o)


def _mlp(cfg: ModelConfig, p: Params, x,
         rules: Optional[ShardingRules]):
    h = L.mlp_apply(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], x))
    return h


def make_layer_fn(cfg: ModelConfig, rules: Optional[ShardingRules],
                  remat: bool = False):
    def layer(x, p, positions):
        x = x + _attn_train(cfg, p, x, positions, rules)
        x = x + _mlp(cfg, p, x, rules)
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return x
    if remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    return layer


# ---------------------------------------------------------------------------
# Forward (training / scoring) — no cache
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions: Optional[jax.Array] = None, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = False,
            inputs_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B,S) -> logits (B,S,V).  ``inputs_embeds`` overrides the
    token embedding (VLM patches path)."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if inputs_embeds is not None:
        x = inputs_embeds
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    layer = make_layer_fn(cfg, rules, remat)

    def body(x, p):
        return layer(x, p, positions), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    if rules is not None:
        logits = constrain(logits, rules, ("batch", "seq", "vocab"))
    return logits


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> jax.Array:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs, guard=guard, rules=rules,
                     remat=remat)
    return L.softmax_cross_entropy(logits, labels, batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving — prefill + decode over the paged-slab cache
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
            tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            positions: Optional[jax.Array] = None,
            inputs_embeds: Optional[jax.Array] = None
            ) -> Tuple[KV.PagedKVCache, jax.Array]:
    """Process the prompt, fill the KV slabs, return last-position logits."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    if inputs_embeds is not None:
        x = inputs_embeds
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def body(carry, inp):
        x, kc, vc = carry
        p, lidx = inp
        q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache, k=kc, v=vc)
        tmp = KV.write_prefill_kv(tmp, lidx, k.astype(kc.dtype),
                                  v.astype(vc.dtype), guard)
        o = L.chunked_attention(q, k, v, causal=True,
                                window=cfg.attn_window, rules=rules)
        x = x + L.out_proj(cfg, p["attn"], o)
        x = x + _mlp(cfg, p, x, rules)
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return (x, tmp.k, tmp.v), None

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc), _ = jax.lax.scan(body, (x, cache.k, cache.v),
                                  (params["layers"], lidxs))
    cache = dataclasses.replace(cache, k=kc, v=vc,
                                seq_lens=cache.seq_lens + S)
    x = L.apply_norm(cfg, params["norm_f"], x[:, -1:])
    logits = L.lm_logits(cfg, params["embed"], x)
    return cache, logits[:, 0]


def decode(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None
           ) -> Tuple[KV.PagedKVCache, jax.Array]:
    """One decode step: tokens (B,) -> logits (B,V); appends to cache."""
    B = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens[:, None], guard)
    if positions is None:
        positions = cache.seq_lens[:, None]
    elif positions.ndim == 1:
        positions = positions[:, None]

    def body(carry, inp):
        x, kc, vc = carry
        p, lidx = inp
        q, k, v = L.qkv_proj(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x))
        q, k = L.positions_rope(cfg, q, k, positions)
        tmp = dataclasses.replace(cache, k=kc, v=vc)
        tmp = KV.append_token_kv(tmp, lidx, k.astype(kc.dtype),
                                 v.astype(vc.dtype), guard)
        k_hist, v_hist = KV.gather_layer_kv(tmp, lidx, guard, rules)
        o = L.decode_attention(q, k_hist.astype(q.dtype),
                               v_hist.astype(q.dtype),
                               cache.seq_lens + 1,
                               window=cfg.attn_window)
        x = x + L.out_proj(cfg, p["attn"], o)
        x = x + _mlp(cfg, p, x, rules)
        return (x, tmp.k, tmp.v), None

    lidxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, kc, vc), _ = jax.lax.scan(body, (x, cache.k, cache.v),
                                  (params["layers"], lidxs))
    cache = dataclasses.replace(cache, k=kc, v=vc,
                                seq_lens=cache.seq_lens + 1)
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return cache, logits[:, 0]
