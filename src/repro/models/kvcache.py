"""KV / state caches for serving — slab-paged pools with Guardian fencing.

Two levels of pooling (see DESIGN.md §Hardware adaptation):

1. **Slab-paged pool** (this module, used by the sharded serve steps):
   the pool is ``(L, slots, pages_per_slot, page, KH, D)``; a *slot* is a
   pow2-partitionable sequence slot (tenants own contiguous pow2 slot
   ranges), and pages within a slot's slab are indirected through a
   per-slot page table.  Two data-dependent index spaces → two fences:

       slot ids  — fenced with the tenant's (base, mask)  [space "kv"]
       page ids  — fenced into the slab [0, pages_per_slot) [space "page"]

   Both batch and slot axes shard over the data axes, so every gather is
   shard-local under GSPMD (no cross-host page traffic).

2. **Global paged pool** (``k.ndim == 5``: ``(L, P_total, page, KH, D)``,
   the continuous-batching serve layout + the Pallas kernel
   `kernels/paged_attention`): one flat page pool shared by every tenant,
   with per-request page lists in *virtual* page ids.  Virtual ids are
   fenced into the owning tenant's extent (space "kv", per-row params),
   then translated virt->phys through the manager-owned
   ``GuardSpec.page_map`` and clamped into the pool (space "page") — see
   :func:`repro.models.guard.fence_pages`.  Elastic compaction rewrites
   the map instead of moving KV bytes.  The same indirection is fenced in
   the Pallas kernel's scalar-prefetch on TPU — the closest analogue of
   the paper's PTX fence.

SSM/recurrent state uses the same slot discipline: ``(L, slots, ...state)``
with fenced slot ids (space "state").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.guard import GuardSpec, fence, fence_pages

PAGE_SIZE = 64


def _pow2_at_least(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Paged KV pool (pytree).

    Two layouts, told apart by rank:

    * slab (6-dim): k/v ``(L, slots, P, page, KH, D)`` — page_table holds
      slab-relative logical->physical page ids, slot_ids pick the slab;
    * global (5-dim): k/v ``(L, P_total, page, KH, D)`` — page_table holds
      *virtual* page ids into the shared pool (slot_ids are unused and
      kept only for pytree-shape compatibility).
    """

    k: jax.Array
    v: jax.Array
    page_table: jax.Array     # (B, P) int32: logical page -> physical page
    slot_ids: jax.Array       # (B,) int32: request -> pool slot
    seq_lens: jax.Array       # (B,) int32: tokens currently cached

    @property
    def global_paged(self) -> bool:
        return self.k.ndim == 5

    @property
    def pages_per_slot(self) -> int:
        return self.k.shape[1] if self.global_paged else self.k.shape[2]

    @property
    def page_size(self) -> int:
        return self.k.shape[2] if self.global_paged else self.k.shape[3]

    @property
    def max_len(self) -> int:
        return self.page_table.shape[1] * self.page_size


def kv_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                  *, slots: Optional[int] = None, page_size: int = PAGE_SIZE,
                  dtype=jnp.bfloat16, n_layers: Optional[int] = None
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract shapes for the cache (dry-run / eval_shape safe)."""
    L = n_layers if n_layers is not None else cfg.decoder_layers
    slots = slots or _pow2_at_least(batch)
    pages = max(max_len // page_size, 1)
    kv_shape = (L, slots, pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(kv_shape, dtype),
        "v": jax.ShapeDtypeStruct(kv_shape, dtype),
        "page_table": jax.ShapeDtypeStruct((batch, pages), jnp.int32),
        "slot_ids": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "seq_lens": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def kv_cache_axes() -> Dict[str, Tuple]:
    """Logical sharding axes matching kv_cache_spec order."""
    kv = (None, "pages", None, None, "kv_heads", None)
    return {"k": kv, "v": kv, "page_table": ("batch", None),
            "slot_ids": ("batch",), "seq_lens": ("batch",)}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  slots: Optional[int] = None, page_size: int = PAGE_SIZE,
                  dtype=jnp.bfloat16, n_layers: Optional[int] = None
                  ) -> PagedKVCache:
    spec = kv_cache_spec(cfg, batch, max_len, slots=slots,
                         page_size=page_size, dtype=dtype, n_layers=n_layers)
    pages = spec["page_table"].shape[1]
    return PagedKVCache(
        k=jnp.zeros(spec["k"].shape, dtype),
        v=jnp.zeros(spec["v"].shape, dtype),
        # identity mapping by default (fresh slabs)
        page_table=jnp.broadcast_to(
            jnp.arange(pages, dtype=jnp.int32)[None, :], (batch, pages)
        ).copy(),
        slot_ids=jnp.arange(batch, dtype=jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
    )


def init_global_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                         total_pages: int, *,
                         page_size: int = PAGE_SIZE,
                         dtype=jnp.float32,
                         n_layers: Optional[int] = None) -> PagedKVCache:
    """Global paged pool: one ``(L, total_pages, page, KH, D)`` tensor
    shared by every tenant; each batch row carries ``max_len //
    page_size`` virtual page ids (see module docstring, layout 2)."""
    L = n_layers if n_layers is not None else cfg.decoder_layers
    pages_per_req = max(max_len // page_size, 1)
    shape = (L, total_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return PagedKVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        page_table=jnp.zeros((batch, pages_per_req), jnp.int32),
        slot_ids=jnp.zeros((batch,), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Fenced read / write paths
# ---------------------------------------------------------------------------

def _fenced_phys_pages(cache: PagedKVCache, table: jax.Array,
                       guard: Optional[GuardSpec]) -> jax.Array:
    """Global layout: virtual page ids -> fenced physical page ids.

    The virtual ids are fenced into the owning tenant's extent (space
    "kv" — per-row params on the serve path), translated through the
    manager's page_map, then clamped into the pool (space "page")."""
    virt = fence(guard, "kv", table)
    return fence_pages(guard, virt)


def gather_layer_kv(cache: PagedKVCache, layer: jax.Array,
                    guard: Optional[GuardSpec] = None,
                    rules=None) -> Tuple[jax.Array, jax.Array]:
    """Read the full (paged) KV history for every request at one layer.

    Returns k, v: (B, S_max, KH, D) where S_max = pages*page.  Invalid tail
    positions are masked by the caller via ``seq_lens``.
    """
    from repro.distributed.sharding import constrain
    if cache.global_paged:
        phys = _fenced_phys_pages(cache, cache.page_table, guard)  # (B,P)
        k_l = jax.lax.dynamic_index_in_dim(cache.k, layer, axis=0,
                                           keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(cache.v, layer, axis=0,
                                           keepdims=False)
        k_p = jnp.take(k_l, phys, axis=0)      # (B, P, page, KH, D)
        v_p = jnp.take(v_l, phys, axis=0)
        B, P, page, KH, D = k_p.shape
        return (k_p.reshape(B, P * page, KH, D),
                v_p.reshape(B, P * page, KH, D))
    slots = fence(guard, "kv", cache.slot_ids)            # (B,)
    pages = fence(guard, "page", cache.page_table)        # (B,P)
    k_l = jax.lax.dynamic_index_in_dim(cache.k, layer, axis=0,
                                       keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(cache.v, layer, axis=0,
                                       keepdims=False)
    # slot gather: (B, P, page, KH, D).  NOTE (§Perf H3 iter2, refuted):
    # pinning this gather's output to batch sharding does NOT stop the
    # partitioner from replicating the pool slice (the replication happens
    # inside the gather lowering) and costs an extra copy — measured 20-25%
    # regression on decode cells, so no constraint here.  The real fix is
    # shard-local pools (documented in EXPERIMENTS.md §Perf H3).
    k_s = jnp.take(k_l, slots, axis=0)
    v_s = jnp.take(v_l, slots, axis=0)
    # page indirection within each request's slab
    k_p = jnp.take_along_axis(
        k_s, pages[:, :, None, None, None], axis=1)
    v_p = jnp.take_along_axis(
        v_s, pages[:, :, None, None, None], axis=1)
    B, P, page, KH, D = k_p.shape
    return (k_p.reshape(B, P * page, KH, D),
            v_p.reshape(B, P * page, KH, D))


def append_token_kv(cache: PagedKVCache, layer: jax.Array,
                    k_new: jax.Array, v_new: jax.Array,
                    guard: Optional[GuardSpec] = None) -> PagedKVCache:
    """Write one new token's K,V per request at ``layer`` (decode step).

    k_new/v_new: (B, 1, KH, D).  The write position is data-dependent
    (seq_lens) — slot, page and in-page offsets are all fenced.
    """
    B = k_new.shape[0]
    page_size = cache.page_size
    pos = cache.seq_lens                                   # (B,)
    logical_page = pos // page_size
    offset = pos % page_size
    if cache.global_paged:
        virt = jnp.take_along_axis(cache.page_table,
                                   logical_page[:, None], axis=1)[:, 0]
        phys = _fenced_phys_pages(cache, virt, guard)      # (B,)
        idx_l = jnp.broadcast_to(jnp.asarray(layer, jnp.int32), (B,))
        k = cache.k.at[idx_l, phys, offset].set(
            k_new[:, 0], mode="promise_in_bounds")
        v = cache.v.at[idx_l, phys, offset].set(
            v_new[:, 0], mode="promise_in_bounds")
        return dataclasses.replace(cache, k=k, v=v)
    slots = fence(guard, "kv", cache.slot_ids)
    phys = jnp.take_along_axis(cache.page_table,
                               logical_page[:, None], axis=1)[:, 0]
    phys = fence(guard, "page", phys)
    idx_l = jnp.broadcast_to(jnp.asarray(layer, jnp.int32), (B,))
    scat = jnp.stack([idx_l, slots, phys, offset], axis=1)  # (B,4)
    k = cache.k.at[scat[:, 0], scat[:, 1], scat[:, 2], scat[:, 3]].set(
        k_new[:, 0], mode="promise_in_bounds")
    v = cache.v.at[scat[:, 0], scat[:, 1], scat[:, 2], scat[:, 3]].set(
        v_new[:, 0], mode="promise_in_bounds")
    return dataclasses.replace(cache, k=k, v=v)


def write_prefill_kv(cache: PagedKVCache, layer: jax.Array,
                     k_new: jax.Array, v_new: jax.Array,
                     guard: Optional[GuardSpec] = None,
                     mode: str = "permute") -> PagedKVCache:
    """Write a full prefill's K,V (B, S, KH, D) into the slabs at ``layer``.

    S is padded to a page multiple.  Pages go through the (fenced) page
    table; slots through the (fenced) slot ids.

    Two formulations (§Perf hillclimb H2):

    * ``scatter``  — direct 4D scatter ``pool[l, slot, phys, off] = kv``.
      Semantically obvious, but the layer-indexed scatter is opaque to the
      SPMD partitioner: it replicates the full (slots, P, page, KH, D)
      pool slice per device (observed: 21.5 GB f32 all-gathers per layer).
    * ``permute`` — collective-free: (1) tiny int32 scatter builds the
      inverse page permutation per slab, (2) a batch-aligned
      take_along_axis materializes each request's slab (local), (3) a
      one-hot einsum places slabs into slot rows (SPMD-friendly
      contraction), (4) dynamic_update_slice writes the layer slice
      (unsharded dim — local).  Fences are applied to the same indices,
      so the isolation guarantee is unchanged.
    """
    B, S, KH, D = k_new.shape
    page_size = cache.page_size
    pad = (-S) % page_size
    if pad:
        k_new = jnp.pad(k_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_new = jnp.pad(v_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    n_pages = S // page_size
    if cache.global_paged:
        phys = _fenced_phys_pages(
            cache, cache.page_table[:, :n_pages], guard)          # (B,n)
        k_pg = k_new.reshape(B, n_pages, page_size, KH, D)
        v_pg = v_new.reshape(B, n_pages, page_size, KH, D)
        ll = jnp.broadcast_to(jnp.asarray(layer, jnp.int32), (B, n_pages))
        k = cache.k.at[ll, phys].set(
            k_pg.astype(cache.k.dtype), mode="promise_in_bounds")
        v = cache.v.at[ll, phys].set(
            v_pg.astype(cache.v.dtype), mode="promise_in_bounds")
        return dataclasses.replace(cache, k=k, v=v)
    slots = fence(guard, "kv", cache.slot_ids)                    # (B,)
    pages = fence(guard, "page", cache.page_table[:, :n_pages])   # (B,n)
    k_pg = k_new.reshape(B, n_pages, page_size, KH, D)
    v_pg = v_new.reshape(B, n_pages, page_size, KH, D)

    if mode == "scatter":
        bb = jnp.broadcast_to(slots[:, None], (B, n_pages))
        ll = jnp.broadcast_to(jnp.asarray(layer, jnp.int32), (B, n_pages))
        k = cache.k.at[ll, bb, pages].set(k_pg, mode="promise_in_bounds")
        v = cache.v.at[ll, bb, pages].set(v_pg, mode="promise_in_bounds")
        return dataclasses.replace(cache, k=k, v=v)

    P_slab = cache.pages_per_slot
    S_slots = cache.k.shape[1]
    # (1) inverse page permutation + write mask — tiny int32 scatters
    bidx = jnp.broadcast_to(
        jnp.arange(B, dtype=jnp.int32)[:, None], (B, n_pages))
    logical = jnp.broadcast_to(
        jnp.arange(n_pages, dtype=jnp.int32)[None, :], (B, n_pages))
    inv = jnp.zeros((B, P_slab), jnp.int32).at[bidx, pages].set(
        logical, mode="drop")
    wrote = jnp.zeros((B, P_slab), bool).at[bidx, pages].set(
        True, mode="drop")

    def place(pool, new_pg):
        # (2) per-request slab via batch-aligned gather (local)
        slab_new = jnp.take_along_axis(
            new_pg, inv[:, :, None, None, None], axis=1)
        # keep old contents where this prefill wrote nothing
        old = jnp.take(jax.lax.dynamic_index_in_dim(
            pool, layer, axis=0, keepdims=False), slots, axis=0)
        slab = jnp.where(wrote[:, :, None, None, None],
                         slab_new.astype(pool.dtype), old)
        # (3) slot placement as a one-hot contraction (SPMD-friendly)
        oh = jax.nn.one_hot(slots, S_slots, dtype=pool.dtype)    # (B,S_sl)
        # rows not owned by any request keep their old value
        owned = jnp.einsum("bs,b...->s...", oh, jnp.ones(
            (B, 1, 1, 1, 1), pool.dtype))                        # (S_sl,1..)
        placed = jnp.einsum("bs,bpqkd->spqkd", oh, slab)
        pool_l = jax.lax.dynamic_index_in_dim(pool, layer, axis=0,
                                              keepdims=False)
        new_l = jnp.where(owned > 0, placed, pool_l)
        # (4) layer write on the unsharded axis (local)
        return jax.lax.dynamic_update_slice_in_dim(
            pool, new_l[None], layer, axis=0)

    k = place(cache.k, k_pg)
    v = place(cache.v, v_pg)
    return dataclasses.replace(cache, k=k, v=v)


def advance(cache: PagedKVCache, n: int = 1) -> PagedKVCache:
    return dataclasses.replace(cache, seq_lens=cache.seq_lens + n)


# ---------------------------------------------------------------------------
# SSM / recurrent state pool
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StateCache:
    """Recurrent state pool (pytree).

    ``pools`` maps state name -> (L_kind, slots, *state_shape) arrays;
    slot ids are fenced with the tenant's partition (space "state").
    """

    pools: Dict[str, jax.Array]
    slot_ids: jax.Array        # (B,)
    seq_lens: jax.Array        # (B,)

    def read(self, name: str, layer: jax.Array,
             guard: Optional[GuardSpec] = None) -> jax.Array:
        slots = fence(guard, "state", self.slot_ids)
        pool_l = jax.lax.dynamic_index_in_dim(
            self.pools[name], layer, axis=0, keepdims=False)
        return jnp.take(pool_l, slots, axis=0)

    def write(self, name: str, layer: jax.Array, value: jax.Array,
              guard: Optional[GuardSpec] = None) -> "StateCache":
        slots = fence(guard, "state", self.slot_ids)
        B = value.shape[0]
        ll = jnp.broadcast_to(jnp.asarray(layer, jnp.int32), (B,))
        pools = dict(self.pools)
        pools[name] = pools[name].at[ll, slots].set(
            value, mode="promise_in_bounds")
        return dataclasses.replace(self, pools=pools)
