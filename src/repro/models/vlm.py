"""qwen2-vl-2b backbone — dense decoder with M-RoPE and a stubbed vision
frontend (assignment: ``input_specs()`` provides precomputed patch
embeddings; the ViT tower is out of scope).

Multimodal fusion: patch embeddings replace the token embeddings at the
image positions (first ``n_patches`` slots of the sequence by convention);
M-RoPE 3-component position ids (temporal/height/width) arrive with the
batch.  Everything else delegates to the dense transformer.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models import transformer as TF
from repro.models.guard import GuardSpec

Params = Dict[str, Any]

init = TF.init
param_logical_axes = TF.param_logical_axes


def fuse_inputs(params: Params, tokens: jax.Array, patches: jax.Array,
                guard: Optional[GuardSpec] = None) -> jax.Array:
    """Token embeddings with the first n_patches positions replaced by the
    (precomputed) patch embeddings."""
    x = L.embed_tokens(params["embed"], tokens, guard)
    n_patch = patches.shape[1]
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :, None]
    pad = x.shape[1] - n_patch
    patches_full = jnp.pad(
        patches.astype(x.dtype), ((0, 0), (0, pad), (0, 0)))
    return jnp.where(pos < n_patch, patches_full, x)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            patches: jax.Array, positions3: jax.Array, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = False) -> jax.Array:
    x = fuse_inputs(params, tokens, patches, guard)
    return TF.forward(cfg, params, tokens, positions3, guard=guard,
                      rules=rules, remat=remat, inputs_embeds=x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> jax.Array:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs, batch["patches"],
                     batch["positions"], guard=guard, rules=rules,
                     remat=remat)
    # loss only on text positions (patch slots are inputs, not targets)
    n_patch = batch["patches"].shape[1]
    text_mask = (jnp.arange(labels.shape[1], dtype=jnp.int32)[None, :]
                 >= n_patch - 1).astype(jnp.float32)
    mask = batch.get("mask")
    mask = text_mask if mask is None else mask * text_mask
    return L.softmax_cross_entropy(logits, labels, mask)


def prefill(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
            tokens: jax.Array, patches: jax.Array, positions3: jax.Array,
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None
            ) -> Tuple[KV.PagedKVCache, jax.Array]:
    x = fuse_inputs(params, tokens, patches, guard)
    return TF.prefill(cfg, params, cache, tokens, guard=guard, rules=rules,
                      positions=positions3, inputs_embeds=x)


def decode(cfg: ModelConfig, params: Params, cache: KV.PagedKVCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None
           ) -> Tuple[KV.PagedKVCache, jax.Array]:
    # text-only decode: M-RoPE components all equal the text position
    if positions is None:
        positions = cache.seq_lens[:, None]
    if positions.ndim == 2:
        positions = jnp.repeat(positions[..., None], 3, axis=-1)
    return TF.decode(cfg, params, cache, tokens, guard=guard, rules=rules,
                     positions=positions)
