"""xLSTM — alternating mLSTM / sLSTM blocks (xlstm-350m).

* **mLSTM** (even layers): matrix memory C_t = f_t C_{t-1} + i_t v_t k_tᵀ,
  read y_t = (q_t·C_t) / max(|q_t·n_t|, 1).  Parallelizable — implemented
  chunkwise on top of the shared SSD machinery (same recurrence with
  scalar gates).  Simplification vs the paper: sigmoid input gate instead
  of the stabilized exp gate (recorded in DESIGN.md §Changed-assumptions).
* **sLSTM** (odd layers): scalar memory with recurrent gate connections —
  strictly sequential, lax.scan over time with exp-gate stabilization.

Attention-free ⇒ KV fencing n/a; the Guardian-guarded resource is the
recurrent **state pool** (fenced slot ids, space "state").  Pure recurrent
state ⇒ long_500k runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models.guard import GuardSpec
from repro.models.ssd import ssd_chunked, ssd_step

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d, H, D = cfg.d_model, cfg.n_heads, cfg.head_dim
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    dt = L.dtype_of(cfg)
    return {
        "wq": L.dense_init(k1, d, H * D, dt),
        "wk": L.dense_init(k2, d, H * D, dt),
        "wv": L.dense_init(k3, d, H * D, dt),
        "w_if": L.dense_init(k4, d, 2 * H, dt),   # input & forget gates
        "wo_gate": L.dense_init(k5, d, H * D, dt),
        "wo": L.dense_init(k6, H * D, d, dt,
                           scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "norm": L.norm_init(cfg),
    }


def mlstm_axes(cfg: ModelConfig) -> Params:
    return {
        "wq": ("embed", "heads"), "wk": ("embed", "heads"),
        "wv": ("embed", "heads"), "w_if": ("embed", None),
        "wo_gate": ("embed", "heads"), "wo": ("heads", "embed"),
        "norm": L.norm_axes(cfg),
    }


def _mlstm_gates(p, xn):
    gates = xn @ p["w_if"]
    H2 = gates.shape[-1] // 2
    i_raw, f_raw = gates[..., :H2], gates[..., H2:]
    i_gate = jax.nn.sigmoid(i_raw.astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    return i_gate, log_f


def _mlstm_qkv(cfg, p, xn):
    B, S, _ = xn.shape
    H, D = cfg.n_heads, cfg.head_dim
    q = (xn @ p["wq"]).reshape(B, S, H, D)
    k = (xn @ p["wk"]).reshape(B, S, H, D) / math.sqrt(D)
    v = (xn @ p["wv"]).reshape(B, S, H, D)
    return q, k, v


def mlstm_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                h0: Optional[jax.Array] = None,
                n0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,S,d) -> (y, h_final (B,H,D,D), n_final (B,H,D,1))."""
    B, S, _ = x.shape
    H, D = cfg.n_heads, cfg.head_dim
    xn = L.apply_norm(cfg, p["norm"], x)
    q, k, v = _mlstm_qkv(cfg, p, xn)
    i_gate, log_f = _mlstm_gates(p, xn)                  # (B,S,H)
    b = k.astype(jnp.float32) * i_gate[..., None]        # i-scaled keys
    y_num, h_f = ssd_chunked(v.astype(jnp.float32), log_f, b,
                             q.astype(jnp.float32), h0=h0,
                             chunk=cfg.ssm.chunk if cfg.ssm else 64)
    ones = jnp.ones((B, S, H, 1), jnp.float32)
    y_den, n_f = ssd_chunked(ones, log_f, b, q.astype(jnp.float32),
                             h0=n0, chunk=cfg.ssm.chunk if cfg.ssm else 64)
    y = y_num / jnp.maximum(jnp.abs(y_den), 1.0)
    o_gate = jax.nn.sigmoid((xn @ p["wo_gate"]).astype(jnp.float32))
    y = (y.reshape(B, S, H * D) * o_gate).astype(x.dtype)
    return y @ p["wo"], h_f, n_f


def mlstm_step(cfg: ModelConfig, p: Params, x: jax.Array,
               h: jax.Array, n: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,1,d); h (B,H,D,D); n (B,H,D,1)."""
    B = x.shape[0]
    H, D = cfg.n_heads, cfg.head_dim
    xn = L.apply_norm(cfg, p["norm"], x)
    q, k, v = _mlstm_qkv(cfg, p, xn)
    i_gate, log_f = _mlstm_gates(p, xn)
    b = (k[:, 0].astype(jnp.float32) * i_gate[:, 0, :, None])
    y_num, h_new = ssd_step(v[:, 0].astype(jnp.float32), log_f[:, 0],
                            b, q[:, 0].astype(jnp.float32), h)
    ones = jnp.ones((B, H, 1), jnp.float32)
    y_den, n_new = ssd_step(ones, log_f[:, 0], b,
                            q[:, 0].astype(jnp.float32), n)
    y = y_num / jnp.maximum(jnp.abs(y_den), 1.0)
    o_gate = jax.nn.sigmoid((xn @ p["wo_gate"]).astype(jnp.float32))
    y = (y.reshape(B, 1, H * D) * o_gate).astype(x.dtype)
    return y @ p["wo"], h_new, n_new


# ---------------------------------------------------------------------------
# sLSTM block — sequential scalar memory with recurrent connections
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    k1, k2, k3 = jax.random.split(key, 3)
    dt = L.dtype_of(cfg)
    return {
        "w_in": L.dense_init(k1, d, 4 * d, dt),   # z,i,f,o pre-activations
        "r": (jax.random.normal(k2, (H, hd, 4 * hd), jnp.float32)
              / math.sqrt(hd)).astype(dt),        # block-diag recurrent
        "wo": L.dense_init(k3, d, d, dt,
                           scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "norm": L.norm_init(cfg),
    }


def slstm_axes(cfg: ModelConfig) -> Params:
    return {"w_in": ("embed", None), "r": (None, None, None),
            "wo": ("embed", "embed_nofsdp"), "norm": L.norm_axes(cfg)}


def _slstm_cell(cfg, p, pre, state):
    """One time step.  pre: (B, 4d) input pre-activations.
    state = (c, n, h, m): c,n,h (B,d); m (B,H)."""
    H = cfg.n_heads
    B = pre.shape[0]
    d = pre.shape[-1] // 4
    hd = d // H
    c, n, h, m = state
    rec = jnp.einsum("bhx,hxy->bhy",
                     h.reshape(B, H, hd).astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    zifo = pre.astype(jnp.float32) + rec
    z_r, i_r, f_r, o_r = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    i_h = i_r.reshape(B, H, hd)
    f_h = f_r.reshape(B, H, hd)
    # exp gates with max-state stabilization (per head: use head max)
    i_s = jnp.max(i_h, axis=-1)
    f_s = jnp.max(f_h, axis=-1)
    m_new = jnp.maximum(f_s + m, i_s)                       # (B,H)
    i_gate = jnp.exp(i_h - m_new[..., None]).reshape(B, d)
    f_gate = jnp.exp(f_h + (m - m_new)[..., None]).reshape(B, d)
    c_new = f_gate * c + i_gate * z
    n_new = f_gate * n + i_gate
    h_new = o * (c_new / jnp.maximum(jnp.abs(n_new), 1e-6))
    return (c_new, n_new, h_new, m_new)


def slstm_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                state0=None) -> Tuple[jax.Array, Tuple]:
    B, S, d = x.shape
    xn = L.apply_norm(cfg, p["norm"], x)
    pre = xn @ p["w_in"]                                    # (B,S,4d)
    if state0 is None:
        state0 = slstm_zero_state(cfg, B)

    def step(st, pre_t):
        st = _slstm_cell(cfg, p, pre_t, st)
        return st, st[2]

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B,S,d)
    return y @ p["wo"], state


def slstm_zero_state(cfg: ModelConfig, B: int):
    d, H = cfg.d_model, cfg.n_heads
    z = jnp.zeros((B, d), jnp.float32)
    return (z, z, z, jnp.full((B, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# Full model — pairs of (mLSTM, sLSTM) blocks scanned
# ---------------------------------------------------------------------------

def init(rng, cfg: ModelConfig) -> Params:
    n_pairs = cfg.n_layers // 2
    k_emb, k_m, k_s = jax.random.split(rng, 3)
    m_stack = jax.vmap(lambda k: mlstm_init(k, cfg))(
        jax.random.split(k_m, n_pairs))
    s_stack = jax.vmap(lambda k: slstm_init(k, cfg))(
        jax.random.split(k_s, n_pairs))
    return {
        "embed": L.embedding_init(k_emb, cfg),
        "mlstm": m_stack,
        "slstm": s_stack,
        "norm_f": L.norm_init(cfg),
    }


def param_logical_axes(cfg: ModelConfig) -> Params:
    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embedding_axes(cfg),
        "mlstm": stack(mlstm_axes(cfg)),
        "slstm": stack(slstm_axes(cfg)),
        "norm_f": L.norm_axes(cfg),
    }


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions: Optional[jax.Array] = None, *,
            guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = False) -> jax.Array:
    x = L.embed_tokens(params["embed"], tokens, guard)

    def pair(x, ps):
        pm, psl = ps
        y, _, _ = mlstm_apply(cfg, pm, x)
        x = x + y
        y, _ = slstm_apply(cfg, psl, x)
        x = x + y
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return x, None

    body = pair
    if remat:
        body = jax.checkpoint(
            pair, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"]))
    x = L.apply_norm(cfg, params["norm_f"], x)
    return L.lm_logits(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> jax.Array:
    tokens = batch["tokens"]
    logits = forward(cfg, params, tokens[:, :-1], guard=guard,
                     rules=rules, remat=remat)
    return L.softmax_cross_entropy(logits, tokens[:, 1:],
                                   batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving — recurrent state pool only (no KV)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16,
               slots=None) -> KV.StateCache:
    n_pairs = cfg.n_layers // 2
    H, D, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    if slots is None:
        slots = max(1 << (batch - 1).bit_length(), 1) if batch > 1 else 1
    pools = {
        "mlstm_h": jnp.zeros((n_pairs, slots, H, D, D), jnp.float32),
        "mlstm_n": jnp.zeros((n_pairs, slots, H, D, 1), jnp.float32),
        "slstm_c": jnp.zeros((n_pairs, slots, d), jnp.float32),
        "slstm_n": jnp.zeros((n_pairs, slots, d), jnp.float32),
        "slstm_h": jnp.zeros((n_pairs, slots, d), jnp.float32),
        "slstm_m": jnp.full((n_pairs, slots, H), -1e30, jnp.float32),
    }
    return KV.StateCache(pools=pools,
                         slot_ids=jnp.arange(batch, dtype=jnp.int32),
                         seq_lens=jnp.zeros((batch,), jnp.int32))


def prefill(cfg: ModelConfig, params: Params, cache: KV.StateCache,
            tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
            rules: Optional[ShardingRules] = None,
            positions: Optional[jax.Array] = None
            ) -> Tuple[KV.StateCache, jax.Array]:
    """Process the prompt full-sequence, capture per-layer final recurrent
    states into the (fenced) state pool, return last-position logits."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, guard)
    n_pairs = cfg.n_layers // 2

    def pair(carry, inp):
        x, cache = carry
        li, pm, psl = inp
        y, h_f, n_f = mlstm_apply(cfg, pm, x)
        cache = cache.write("mlstm_h", li, h_f, guard)
        cache = cache.write("mlstm_n", li, n_f, guard)
        x = x + y
        y, st = slstm_apply(cfg, psl, x)
        cache = cache.write("slstm_c", li, st[0], guard)
        cache = cache.write("slstm_n", li, st[1], guard)
        cache = cache.write("slstm_h", li, st[2], guard)
        cache = cache.write("slstm_m", li, st[3], guard)
        x = x + y
        if rules is not None:
            x = constrain(x, rules, ("batch", "seq", None))
        return (x, cache), None

    (x, cache), _ = jax.lax.scan(
        pair, (x, cache),
        (jnp.arange(n_pairs, dtype=jnp.int32),
         params["mlstm"], params["slstm"]))
    cache = dataclasses.replace(cache, seq_lens=cache.seq_lens + S)
    x = L.apply_norm(cfg, params["norm_f"], x[:, -1:])
    logits = L.lm_logits(cfg, params["embed"], x)
    return cache, logits[:, 0]


def decode(cfg: ModelConfig, params: Params, cache: KV.StateCache,
           tokens: jax.Array, *, guard: Optional[GuardSpec] = None,
           rules: Optional[ShardingRules] = None,
           positions: Optional[jax.Array] = None
           ) -> Tuple[KV.StateCache, jax.Array]:
    x = L.embed_tokens(params["embed"], tokens[:, None], guard)
    n_pairs = cfg.n_layers // 2

    def pair(carry, inp):
        x, cache = carry
        li, pm, psl = inp
        h = cache.read("mlstm_h", li, guard)
        n = cache.read("mlstm_n", li, guard)
        y, h, n = mlstm_step(cfg, pm, x, h, n)
        cache = cache.write("mlstm_h", li, h, guard)
        cache = cache.write("mlstm_n", li, n, guard)
        x = x + y
        st = (cache.read("slstm_c", li, guard),
              cache.read("slstm_n", li, guard),
              cache.read("slstm_h", li, guard),
              cache.read("slstm_m", li, guard))
        xn = L.apply_norm(cfg, psl["norm"], x)
        pre = (xn @ psl["w_in"])[:, 0]
        st = _slstm_cell(cfg, psl, pre, st)
        cache = cache.write("slstm_c", li, st[0], guard)
        cache = cache.write("slstm_n", li, st[1], guard)
        cache = cache.write("slstm_h", li, st[2], guard)
        cache = cache.write("slstm_m", li, st[3], guard)
        y = (st[2].astype(x.dtype)[:, None, :]) @ psl["wo"]
        x = x + y
        return (x, cache), None

    (x, cache), _ = jax.lax.scan(
        pair, (x, cache),
        (jnp.arange(n_pairs, dtype=jnp.int32),
         params["mlstm"], params["slstm"]))
    cache = dataclasses.replace(cache, seq_lens=cache.seq_lens + 1)
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return cache, logits[:, 0]
