"""``python -m repro.top`` — the Guardian operator dashboard.

Renders :meth:`GuardianManager.metrics_report` as a terminal dashboard
(:mod:`repro.launch.dashboard` — plain ANSI, curses-free).  By default
it drives a small built-in multi-tenant demo (raw ``GuardianClient``
traffic: module_load / malloc / memcpy_h2d / launch_kernel over a few
drain cycles) so every panel has data; the module is also the reference
for wiring the dashboard to a live manager::

    from repro.launch.dashboard import format_report
    print(format_report(mgr.metrics_report(), registry=mgr.telemetry.registry))

Modes:

* ``--snapshot`` (default): drive ``--cycles`` drain cycles, render
  once, exit 0 — the CI smoke.
* ``--watch``: redraw every ``--interval`` seconds, driving one more
  drain burst per frame, until Ctrl-C.
* ``--json``: dump the raw metrics_report dict instead of rendering.
* ``--prom``: dump the Prometheus text exposition instead.
* ``--trace-out FILE``: additionally write the Chrome/Perfetto trace.

    PYTHONPATH=src python -m repro.top --snapshot --tenants 3
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Tuple

CLEAR = "\x1b[2J\x1b[H"

#: demo arena: small enough to build instantly on CPU
DEMO_SLOTS = 1 << 12
#: per-tenant fence policies cycled across the demo tenants — one of
#: each mode, so the dashboard's policy column and the scheduler's
#: per-policy batching both show up
DEMO_POLICIES = ("bitwise", "modulo", "check")


def _demo_kernel(arena, ptr, n):
    import jax.numpy as jnp

    idx = ptr + jnp.arange(n, dtype=jnp.int32)
    vals = jnp.take(arena, idx, axis=0)
    return arena.at[idx].set(vals * 1.0001 + 1.0), None


def build_demo(n_tenants: int, policies: Tuple[str, ...] = DEMO_POLICIES):
    """A GuardianManager with ``n_tenants`` demo tenants submitting raw
    fenced launches — returns ``(mgr, clients, ptrs)``."""
    import numpy as np

    from repro.core import FencePolicy, GuardianManager

    mgr = GuardianManager(total_slots=DEMO_SLOTS,
                          standalone_fast_path=False)
    clients, ptrs = [], []
    for i in range(n_tenants):
        pol = FencePolicy(policies[i % len(policies)]) if policies \
            else None
        c = mgr.register_tenant(f"tenant{i}",
                                DEMO_SLOTS // (2 * max(n_tenants, 1)),
                                policy=pol, weight=1 + i % 2)
        c.module_load("work", _demo_kernel)
        p = c.malloc(16)
        c.memcpy_h2d(p, np.zeros(16, np.float32))
        clients.append(c)
        ptrs.append(p)
    mgr.synchronize()
    return mgr, clients, ptrs


def drive(mgr, clients, ptrs, cycles: int) -> None:
    """Enqueue ``cycles`` rounds of one launch per tenant, then drain —
    each round lands in (at least) one drain cycle, so the queue-age and
    drain-time histograms fill."""
    for _ in range(max(cycles, 1)):
        for c, p in zip(clients, ptrs):
            c.launch_kernel("work", ptrs=[p], args=(16,))
        mgr.run_queued()


def render(mgr) -> str:
    from repro.launch.dashboard import format_report

    return format_report(mgr.metrics_report(),
                         registry=mgr.telemetry.registry)


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.top", description="Guardian operator dashboard")
    ap.add_argument("--snapshot", action="store_true",
                    help="render once and exit (default)")
    ap.add_argument("--watch", action="store_true",
                    help="redraw every --interval seconds until Ctrl-C")
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--cycles", type=int, default=8,
                    help="demo drain-cycle bursts before the first frame")
    ap.add_argument("--json", action="store_true",
                    help="dump the metrics_report dict as JSON")
    ap.add_argument("--prom", action="store_true",
                    help="dump the Prometheus text exposition")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome/Perfetto event trace JSON")
    args = ap.parse_args(argv)

    mgr, clients, ptrs = build_demo(args.tenants)
    drive(mgr, clients, ptrs, args.cycles)

    def frame() -> str:
        if args.json:
            return json.dumps(mgr.metrics_report(), indent=1,
                              default=str, sort_keys=True)
        if args.prom:
            return mgr.telemetry.registry.to_prometheus()
        return render(mgr)

    if args.watch:
        try:
            while True:
                sys.stdout.write(CLEAR + frame() + "\n")
                sys.stdout.flush()
                time.sleep(args.interval)
                drive(mgr, clients, ptrs, 1)
        except KeyboardInterrupt:
            pass
    else:
        print(frame())
    if args.trace_out:
        with open(args.trace_out, "w") as fh:
            fh.write(mgr.telemetry.trace.to_json())
        print(f"trace: {args.trace_out} "
              f"({len(mgr.telemetry.trace)} events)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
