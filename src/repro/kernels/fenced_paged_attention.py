"""Fenced paged-attention decode kernel — the paper's PTX fence, TPU-native.

This is the closest TPU analogue of Guardian's sandboxed kernel: the
per-sequence page table is **scalar-prefetched into SMEM**, and the fence
``phys = (page_id & mask) | base`` is applied to the page id *inside the
BlockSpec index_map* — i.e. before the page id forms a DMA descriptor,
exactly where the paper patches the PTX register before ``ld.global``.
A corrupted or malicious page table therefore cannot steer the DMA engine
outside the tenant's partition of the shared page pool; like the paper's
bitwise mode, a bad id wraps around inside the tenant's own pages.

Layout (one grid step per (sequence, page)):

    q          (B, H, D)                 queries, one token per sequence
    k_pages    (P_total, page, KH, D)    shared global pool (all tenants)
    v_pages    (P_total, page, KH, D)
    page_table (B, max_pages) int32      logical -> physical (untrusted!)
    seq_lens   (B,) int32
    fence_base (B,) int32                per-row tenant partition base
    fence_mask (B,) int32                per-row tenant partition mask

    grid = (B, max_pages); pages sequentially accumulate online softmax
    in VMEM scratch (m, l, acc); the output row is written at the last
    page.  Cost: 2 integer lane-ops per page DMA (the paper's "two bitwise
    instructions per load").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _fence(idx, base, mask):
    return jax.lax.bitwise_or(jax.lax.bitwise_and(idx, mask), base)


def _kv_index_map(b, p, page_table, seq_lens, base, mask):
    """BlockSpec index map for the page pool: the Guardian fence lands
    here, on the scalar-prefetched page id, before the DMA."""
    phys = _fence(page_table[b, p], base[b], mask[b])
    return (phys, 0, 0, 0)


def _q_index_map(b, p, page_table, seq_lens, base, mask):
    return (b, 0, 0)


def _o_index_map(b, p, page_table, seq_lens, base, mask):
    return (b, 0, 0)


def _kernel(page_table, seq_lens, base, mask,   # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                # VMEM blocks
            o_ref,                              # VMEM out
            m_ref, l_ref, acc_ref):             # VMEM scratch
    b = pl.program_id(0)
    p = pl.program_id(1)
    page = k_ref.shape[1]
    scale = 1.0 / (q_ref.shape[-1] ** 0.5)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (page, KH, D)
    v = v_ref[0].astype(jnp.float32)
    H, D = q.shape
    KH = k.shape[1]
    G = H // KH
    qg = q.reshape(KH, G, D)
    s = jnp.einsum("kgd,pkd->kgp", qg, k)             # (KH, G, page)

    # mask positions beyond the sequence length
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = pos < seq_lens[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # (KH, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])              # (KH, G, page)
    l_new = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    pv = jnp.einsum("kgp,pkd->kgd", pexp, v)          # (KH, G, D)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[..., None]               # (KH, G, D)
        o_ref[0] = o.reshape(H, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fenced_paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                           fence_base, fence_mask, *, interpret=True):
    """q (B,H,D); pools (P,page,KH,D); returns (B,H,D)."""
    B, H, D = q.shape
    P_total, page, KH, D2 = k_pages.shape
    max_pages = page_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), _q_index_map),
            pl.BlockSpec((1, page, KH, D), _kv_index_map),
            pl.BlockSpec((1, page, KH, D), _kv_index_map),
        ],
        out_specs=pl.BlockSpec((1, H, D), _o_index_map),
        scratch_shapes=[
            pltpu.VMEM((KH, H // KH), jnp.float32),       # m
            pltpu.VMEM((KH, H // KH), jnp.float32),       # l
            pltpu.VMEM((KH, H // KH, D), jnp.float32),    # acc
        ],
    )

    kernel = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(page_table.astype(jnp.int32),
                  seq_lens.astype(jnp.int32),
                  fence_base.astype(jnp.int32),
                  fence_mask.astype(jnp.int32),
                  q, k_pages, v_pages)
