"""Fenced paged-attention decode kernel — the paper's PTX fence, TPU-native.

This is the closest TPU analogue of Guardian's sandboxed kernel: the
per-sequence page table is **scalar-prefetched into SMEM**, and the fence
``phys = (page_id & mask) | base`` is applied to the page id *inside the
BlockSpec index_map* — i.e. before the page id forms a DMA descriptor,
exactly where the paper patches the PTX register before ``ld.global``.
A corrupted or malicious page table therefore cannot steer the DMA engine
outside the tenant's partition of the shared page pool; like the paper's
bitwise mode, a bad id wraps around inside the tenant's own pages.

Layout (one grid step per (sequence, page)):

    q          (B, H, D)                 queries, one token per sequence
    k_pages    (P_total, page, KH, D)    shared global pool (all tenants)
    v_pages    (P_total, page, KH, D)
    page_table (B, max_pages) int32      logical -> physical (untrusted!)
    seq_lens   (B,) int32
    fence_base (B,) int32                per-row tenant partition base
    fence_mask (B,) int32                per-row tenant partition mask

    grid = (B, max_pages); pages sequentially accumulate online softmax
    in VMEM scratch (m, l, acc); the output row is written at the last
    page.  Cost: 2 integer lane-ops per page DMA (the paper's "two bitwise
    instructions per load").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _fence(idx, base, mask):
    return jax.lax.bitwise_or(jax.lax.bitwise_and(idx, mask), base)


def _kv_index_map(b, p, page_table, seq_lens, base, mask):
    """BlockSpec index map for the page pool: the Guardian fence lands
    here, on the scalar-prefetched page id, before the DMA."""
    phys = _fence(page_table[b, p], base[b], mask[b])
    return (phys, 0, 0, 0)


def _kv_index_map_mapped(b, p, page_table, seq_lens, base, mask, page_map,
                         *, phys_mask):
    """Virtual-extent variant: the fence clamps the (untrusted) id into
    the tenant's *virtual* page extent, then the manager-owned page_map
    translates virtual -> physical — still inside the index map, before
    the DMA descriptor forms.  The translated id gets a second, static
    clamp to the physical pool (defense in depth: the map itself is a
    trusted operand, but a stale row costs a wrong-page read, never an
    OOB one)."""
    virt = _fence(page_table[b, p], base[b], mask[b])
    phys = jax.lax.bitwise_and(page_map[virt], phys_mask)
    return (phys, 0, 0, 0)


def _q_index_map(b, p, page_table, seq_lens, base, mask, *extra):
    return (b, 0, 0)


def _o_index_map(b, p, page_table, seq_lens, base, mask, *extra):
    return (b, 0, 0)


def _kernel(page_table, seq_lens, base, mask,   # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                # VMEM blocks
            o_ref,                              # VMEM out
            m_ref, l_ref, acc_ref):             # VMEM scratch
    b = pl.program_id(0)
    p = pl.program_id(1)
    page = k_ref.shape[1]
    scale = 1.0 / (q_ref.shape[-1] ** 0.5)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (page, KH, D)
    v = v_ref[0].astype(jnp.float32)
    H, D = q.shape
    KH = k.shape[1]
    G = H // KH
    qg = q.reshape(KH, G, D)
    s = jnp.einsum("kgd,pkd->kgp", qg, k)             # (KH, G, page)

    # mask positions beyond the sequence length
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = pos < seq_lens[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # (KH, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])              # (KH, G, page)
    l_new = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    pv = jnp.einsum("kgp,pkd->kgd", pexp, v)          # (KH, G, D)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[..., None]               # (KH, G, D)
        o_ref[0] = o.reshape(H, D).astype(o_ref.dtype)


def _kernel_mapped(page_table, seq_lens, base, mask, page_map,
                   q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    """Same body — page_map only steers the BlockSpec index maps."""
    _kernel(page_table, seq_lens, base, mask,
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fenced_paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                           fence_base, fence_mask, page_map=None, *,
                           interpret=True):
    """q (B,H,D); pools (P,page,KH,D); returns (B,H,D).

    ``page_map`` (n_virt,) int32, optional: page_table then holds
    *virtual* ids — fenced into the tenant's virtual extent, translated
    through the manager-owned map, and statically clamped to the pool
    (which must be pow2-sized) inside the index map.  This is the
    serve-path layout behind elastic zero-copy compaction."""
    B, H, D = q.shape
    P_total, page, KH, D2 = k_pages.shape
    max_pages = page_table.shape[1]

    if page_map is not None:
        if P_total & (P_total - 1):
            raise ValueError(
                f"page_map translation needs a pow2 physical pool, "
                f"got P_total={P_total}")
        num_scalar = 5
        kernel_fn = _kernel_mapped
        kv_map = functools.partial(_kv_index_map_mapped,
                                   phys_mask=P_total - 1)
    else:
        num_scalar = 4
        kernel_fn = _kernel
        kv_map = _kv_index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), _q_index_map),
            pl.BlockSpec((1, page, KH, D), kv_map),
            pl.BlockSpec((1, page, KH, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, D), _o_index_map),
        scratch_shapes=[
            pltpu.VMEM((KH, H // KH), jnp.float32),       # m
            pltpu.VMEM((KH, H // KH), jnp.float32),       # l
            pltpu.VMEM((KH, H // KH, D), jnp.float32),    # acc
        ],
    )

    kernel = pl.pallas_call(
        kernel_fn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )
    scalars = [page_table.astype(jnp.int32),
               seq_lens.astype(jnp.int32),
               fence_base.astype(jnp.int32),
               fence_mask.astype(jnp.int32)]
    if page_map is not None:
        scalars.append(page_map.astype(jnp.int32))
    return kernel(*scalars, q, k_pages, v_pages)
