"""Blocked causal flash attention (forward) — train/prefill hot path.

Standard TPU flash tiling: grid (B, H, nq, nk); (q_blk x kv_blk) score
tiles live in VMEM/VREGs only (never HBM — the memory-term win the
roofline analysis attributes to this kernel), with online-softmax scratch
carried across the kv dimension.  Block shapes default to MXU-aligned
(128 x 128).  GQA: KV blocks are indexed by head-group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, q_blk, kv_blk, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * kv_blk <= qi * q_blk + q_blk - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (q_blk, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (kv_blk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                    # (q_blk, kv_blk)
        if causal:
            q_pos = qi * q_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 0)
            kv_pos = ki * kv_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 1)
            s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "q_blk", "kv_blk",
                                    "interpret"))
def flash_attention(q, k, v, *, causal=True, q_blk=128, kv_blk=128,
                    interpret=True):
    """q (B,S,H,D); k/v (B,S,KH,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    assert S % q_blk == 0 and S % kv_blk == 0, (S, q_blk, kv_blk)
    nq, nk = S // q_blk, S // kv_blk
    scale = 1.0 / (D ** 0.5)

    # (B, H, S, D) layout for blocking; kv indexed by head group
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = pl.pallas_call(
        functools.partial(_kernel, scale=scale, q_blk=q_blk,
                          kv_blk=kv_blk, causal=causal),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )
    out = kernel(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
