"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively (``interpret=False``); on CPU (this
container, and the test suite) they run in interpret mode, which executes
the kernel body in Python — bit-compatible semantics, validated against
``ref.py`` in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax

from repro.kernels.fenced_gather import fenced_gather as _gather
from repro.kernels.fenced_paged_attention import (
    fenced_paged_attention as _paged,
)
from repro.kernels.fenced_scatter import fenced_scatter as _scatter
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_dispatch import moe_histogram as _hist


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                    fence_base, fence_mask, page_map=None):
    return _paged(q, k_pages, v_pages, page_table, seq_lens,
                  fence_base, fence_mask, page_map,
                  interpret=not _on_tpu())


def gather_rows(table, idx, fence_base, fence_mask):
    return _gather(table, idx, fence_base, fence_mask,
                   interpret=not _on_tpu())


def scatter_pages(pool, pages, page_ids, fence_base, fence_mask):
    return _scatter(pool, pages, page_ids, fence_base, fence_mask,
                    interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal=True, q_blk=128, kv_blk=128):
    return _flash(q, k, v, causal=causal, q_blk=q_blk, kv_blk=kv_blk,
                  interpret=not _on_tpu())


def moe_histogram(expert_ids, num_experts, fence_base, fence_mask):
    return _hist(expert_ids, num_experts, fence_base, fence_mask,
                 interpret=not _on_tpu())
