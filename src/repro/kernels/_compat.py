"""Pallas-TPU API compatibility across jax versions.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(same fields).  Kernels import the name from here so they run on both.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
