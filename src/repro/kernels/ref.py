"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function implements the exact semantics of its kernel — including
the Guardian fence — with plain jax.numpy, so tests can
``assert_allclose`` kernel output against it across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def fence_ref(idx, base, mask):
    return jnp.bitwise_or(jnp.bitwise_and(idx, mask), base)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                        fence_base, fence_mask, page_map=None):
    """q (B,H,D); pools (P,page,KH,D) -> (B,H,D).  float32 math.

    With ``page_map`` the table holds virtual ids: fence into the virtual
    extent, translate through the map, clamp to the (pow2) pool."""
    B, H, D = q.shape
    P_total, page, KH, _ = k_pages.shape
    G = H // KH
    max_pages = page_table.shape[1]
    phys = fence_ref(page_table, fence_base[:, None], fence_mask[:, None])
    if page_map is not None:
        phys = jnp.take(page_map.astype(jnp.int32), phys,
                        axis=0) & (P_total - 1)
    k = k_pages[phys]                    # (B, max_pages, page, KH, D)
    v = v_pages[phys]
    S = max_pages * page
    k = k.reshape(B, S, KH, D).astype(jnp.float32)
    v = v.reshape(B, S, KH, D).astype(jnp.float32)
    qg = q.reshape(B, KH, G, D).astype(jnp.float32)
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) * scale
    pos = jnp.arange(S, dtype=jnp.int32)
    mask = pos[None, :] < seq_lens[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, H, D).astype(q.dtype)


def gather_rows_ref(table, idx, fence_base, fence_mask):
    """Fenced embedding-row gather: table (V, D), idx (N,) -> (N, D)."""
    fenced = fence_ref(idx.astype(jnp.int32), fence_base, fence_mask)
    return jnp.take(table, fenced, axis=0)


def scatter_pages_ref(pool, pages, page_ids, fence_base, fence_mask):
    """Fenced page write: pool (P,page,KH,D); pages (N,page,KH,D);
    page_ids (N,) -> updated pool."""
    fenced = fence_ref(page_ids.astype(jnp.int32), fence_base, fence_mask)
    return pool.at[fenced].set(pages.astype(pool.dtype))


def flash_attention_ref(q, k, v, *, causal=True):
    """q (B,S,H,D), k/v (B,S,KH,D) -> (B,S,H,D).  float32 math."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        pos = jnp.arange(S)
        mask = pos[None, :] <= pos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def moe_histogram_ref(expert_ids, num_experts, fence_base, fence_mask):
    """Fenced expert histogram: ids (T,K) -> counts (num_experts,)."""
    fenced = fence_ref(expert_ids.astype(jnp.int32), fence_base,
                       fence_mask)
    return jnp.zeros((num_experts,), jnp.int32).at[fenced.reshape(-1)].add(
        1, mode="drop")
