"""Fenced page scatter — KV-cache page writes into the shared pool.

The *output* BlockSpec index_map applies the fence to the destination
page id, so the store DMA can only land inside the tenant's partition —
the st.global analogue of the paper's Listing 1.  The pool is aliased
in-place (input_output_aliases), as a real cache write must be.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams


def _fence(idx, base, mask):
    return jax.lax.bitwise_or(jax.lax.bitwise_and(idx, mask), base)


def _pages_index_map(n, ids_ref, base_ref, mask_ref):
    return (n, 0, 0, 0)


def _pool_index_map(n, ids_ref, base_ref, mask_ref):
    return (_fence(ids_ref[n], base_ref[0], mask_ref[0]), 0, 0, 0)


def _kernel(ids_ref, base_ref, mask_ref, pages_ref, pool_in_ref, o_ref):
    o_ref[...] = pages_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0,))
def fenced_scatter(pool, pages, page_ids, fence_base, fence_mask, *,
                   interpret=True):
    """pool (P,page,KH,D); pages (N,page,KH,D); page_ids (N,) -> pool'."""
    P, page, KH, D = pool.shape
    N = pages.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, page, KH, D), _pages_index_map),
            pl.BlockSpec((1, page, KH, D), _pool_index_map),
        ],
        out_specs=pl.BlockSpec((1, page, KH, D), _pool_index_map),
    )
    kernel = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={4: 0},   # pool aliases the output
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )
    return kernel(page_ids.astype(jnp.int32),
                  jnp.asarray([fence_base], jnp.int32),
                  jnp.asarray([fence_mask], jnp.int32),
                  pages, pool)
