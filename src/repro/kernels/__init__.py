"""Pallas TPU kernels for the Guardian hot paths.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret-mode on CPU), ref.py (pure-jnp oracle).
"""

from repro.kernels.ops import (
    flash_attention,
    gather_rows,
    moe_histogram,
    paged_attention,
    scatter_pages,
)

__all__ = ["flash_attention", "gather_rows", "moe_histogram",
           "paged_attention", "scatter_pages"]
