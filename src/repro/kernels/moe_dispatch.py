"""Fenced MoE expert histogram — the dispatch-side counter.

Routing produces data-dependent expert ids; before they become offsets
into per-expert buffers, Guardian fences them into the tenant's expert
partition.  This kernel computes per-expert token counts (the quantity
every capacity-based dispatcher needs) with the fence applied in-kernel
on the VMEM-resident id block — 2 lane-ops per id.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(base_ref, mask_ref, ids_ref, o_ref, *, num_experts, blk):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...].reshape(-1)                       # (blk*K,)
    fenced = jax.lax.bitwise_or(
        jax.lax.bitwise_and(ids, mask_ref[0]), base_ref[0])
    onehot = (fenced[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32,
                                       (ids.shape[0], num_experts), 1))
    o_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)[None]


@functools.partial(jax.jit, static_argnames=("num_experts", "interpret"))
def moe_histogram(expert_ids, num_experts, fence_base, fence_mask, *,
                  interpret=True):
    """expert_ids (T, K) int32 -> counts (num_experts,)."""
    T, K = expert_ids.shape
    blk = min(T, 256)
    pad = (-T) % blk
    if pad:
        # pad with an id that fences to `fence_base`; subtract later
        expert_ids = jnp.pad(expert_ids, ((0, pad), (0, 0)),
                             constant_values=fence_base)
    nt = (T + pad) // blk
    kernel = pl.pallas_call(
        functools.partial(_kernel, num_experts=num_experts, blk=blk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt,),
            in_specs=[pl.BlockSpec((blk, K), lambda t, b, m: (t, 0))],
            out_specs=pl.BlockSpec((1, num_experts), lambda t, b, m: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, num_experts), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )
    counts = kernel(jnp.asarray([fence_base], jnp.int32),
                    jnp.asarray([fence_mask], jnp.int32),
                    expert_ids.astype(jnp.int32))[0]
    if pad:
        counts = counts.at[fence_base].add(-pad * K)
    return counts
