"""Fenced row gather — embedding lookups from a shared vocab arena.

Grid = one step per index block; the indices are scalar-prefetched and the
fence is applied in the *input* BlockSpec index_map, so each (1, D) row
DMA is bounded to the tenant's partition before it is issued.  2 integer
ops per row — the paper's Listing-1 cost model, applied to a gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams


def _fence(idx, base, mask):
    return jax.lax.bitwise_or(jax.lax.bitwise_and(idx, mask), base)


def _table_index_map(i, idx_ref, base_ref, mask_ref):
    return (_fence(idx_ref[i], base_ref[0], mask_ref[0]), 0)


def _out_index_map(i, idx_ref, base_ref, mask_ref):
    return (i, 0)


def _kernel(idx_ref, base_ref, mask_ref, row_ref, o_ref):
    o_ref[...] = row_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fenced_gather(table, idx, fence_base, fence_mask, *, interpret=True):
    """table (V, D); idx (N,) int32 -> (N, D) with fenced row ids."""
    V, D = table.shape
    N = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, D), _table_index_map)],
        out_specs=pl.BlockSpec((1, D), _out_index_map),
    )
    kernel = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )
    return kernel(idx.astype(jnp.int32),
                  jnp.asarray([fence_base], jnp.int32),
                  jnp.asarray([fence_mask], jnp.int32),
                  table)
