"""SLO-aware tenant classes — the per-tenant QoS + containment policy
layer (ROADMAP: performance isolation; Tally / ParvaGPU in PAPERS.md).

Guardian's fences give *memory* isolation; this module gives the
scheduler the vocabulary for *performance* isolation.  A tenant is
registered with (or without) a :class:`TenantClassPolicy`:

* ``latency_critical`` — the tenant's ops carry an SLO budget
  (``queue_age_budget``, in drain cycles).  Its cross-cycle lookahead is
  capped at that budget (an LC op is never held for fusion past its
  SLO), and when its observed EWMA queue age breaches the budget the
  scheduler starts **deferring best-effort batches** at drain-cycle
  boundaries until the signal decays (see
  ``BatchedLaunchScheduler.flush``).
* ``best_effort`` — fills residual batch width under the global (or
  per-class) lookahead and is the class that preemption defers.  With
  ``ElasticPolicy.compute_watermark`` set, a best-effort admission also
  waitlists while EWMA arrival-rate pressure would degrade a registered
  latency-critical tenant (compute-aware admission, core/elastic.py).

The same object folds in the per-tenant *containment* knobs
(``quarantine_after`` / ``evict_after`` / rate thresholds /
per-violation-kind weights): QoS and quarantine are configured in one
place and threaded through ``register_tenant`` together.  A tenant
registered without a class policy behaves bit-identically to the
pre-class scheduler (regression-tested).

Everything here is host-side configuration — no device access, no jax.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Union

from repro.core.quarantine import QuarantinePolicy, WeightedRatePolicy


class TenantClass(enum.Enum):
    """The two service classes (Tally's priority split): latency-critical
    tenants hold SLO budgets; best-effort tenants absorb deferral."""

    LATENCY_CRITICAL = "latency_critical"
    BEST_EFFORT = "best_effort"


@dataclasses.dataclass
class TenantClassPolicy:
    """Per-tenant QoS + containment policy, threaded through
    ``register_tenant(..., tenant_class=...)``.

    Scheduling knobs:

    * ``queue_age_budget`` — the SLO budget in drain cycles.  For a
      latency-critical tenant this caps its fusion lookahead (its ops
      are never held past the budget) and arms best-effort preemption:
      when the tenant's EWMA queue age reaches the budget, queued
      best-effort batches defer at drain-cycle boundaries.
    * ``lookahead_cycles`` — per-class override of the scheduler-global
      lookahead (None inherits the global/adaptive budget).  The
      ``latency_critical`` factory defaults it to 0: LC ops dispatch in
      their submission cycle, best-effort traffic fills residual width.
    * ``ewma_alpha`` — smoothing of the queue-age signal preemption
      reads (same :class:`~repro.core.pressure.Ewma` as everywhere).

    Containment knobs (None/empty = inherit the manager's global
    quarantine policy; any set knob builds a per-tenant
    :class:`~repro.core.quarantine.WeightedRatePolicy` that *replaces*
    the global policy for this tenant):

    * ``quarantine_after`` / ``evict_after`` — absolute weighted-count
      thresholds (the classic :class:`ThresholdPolicy` knobs).
    * ``quarantine_rate`` / ``evict_rate`` — weighted violations per
      drain cycle since admission (a slow leak and a burst differ).
    * ``violation_weights`` — per-kind weights (e.g. ``{"scatter": 4}``
      makes corrupting writes count 4x a stray gather).
    """

    tenant_class: TenantClass
    queue_age_budget: int = 0
    lookahead_cycles: Optional[int] = None
    ewma_alpha: float = 0.5
    quarantine_after: Optional[float] = None
    evict_after: Optional[float] = None
    quarantine_rate: Optional[float] = None
    evict_rate: Optional[float] = None
    violation_weights: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    _qpol: Optional[QuarantinePolicy] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        if isinstance(self.tenant_class, str):
            self.tenant_class = TenantClass(self.tenant_class)
        if self.queue_age_budget < 0:
            raise ValueError("queue_age_budget must be >= 0")
        if self.lookahead_cycles is not None and self.lookahead_cycles < 0:
            raise ValueError("lookahead_cycles must be >= 0 (or None)")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")

    # -- factories ------------------------------------------------------ #
    @classmethod
    def latency_critical(cls, queue_age_budget: int = 2,
                         lookahead_cycles: Optional[int] = 0,
                         **kw) -> "TenantClassPolicy":
        """An SLO-holding tenant.  The default ``lookahead_cycles=0``
        dispatches its ops in their submission cycle (p99 queue age 0);
        pass a nonzero value (capped at the budget) to trade a bounded
        wait for fuller fused batches."""
        return cls(TenantClass.LATENCY_CRITICAL,
                   queue_age_budget=queue_age_budget,
                   lookahead_cycles=lookahead_cycles, **kw)

    @classmethod
    def best_effort(cls, **kw) -> "TenantClassPolicy":
        """A deferrable tenant: inherits the global/adaptive lookahead
        (fills residual batch width) and is the class preemption and
        compute-aware admission act on."""
        return cls(TenantClass.BEST_EFFORT, **kw)

    # -- scheduling ------------------------------------------------------ #
    @property
    def is_latency_critical(self) -> bool:
        return self.tenant_class is TenantClass.LATENCY_CRITICAL

    @property
    def is_best_effort(self) -> bool:
        return self.tenant_class is TenantClass.BEST_EFFORT

    def hold_budget(self, global_lookahead: int) -> int:
        """The class-resolved fusion lookahead: the per-class override
        (or the global/adaptive budget), additionally capped at the SLO
        budget for latency-critical tenants — an LC op is *never* held
        for fusion past its budget, whatever the knobs say."""
        look = self.lookahead_cycles \
            if self.lookahead_cycles is not None else global_lookahead
        if self.is_latency_critical:
            look = min(look, self.queue_age_budget)
        return look

    # -- containment ----------------------------------------------------- #
    def quarantine_policy(self) -> Optional[QuarantinePolicy]:
        """The per-tenant containment policy this class configures, or
        None when every containment knob is unset (inherit the manager's
        global policy).  Built once and cached — the quarantine poll
        resolves it per dirty cycle."""
        if (self.quarantine_after is None and self.evict_after is None
                and self.quarantine_rate is None
                and self.evict_rate is None
                and not self.violation_weights):
            return None
        if self._qpol is None:
            self._qpol = WeightedRatePolicy(
                quarantine_after=self.quarantine_after,
                evict_after=self.evict_after,
                quarantine_rate=self.quarantine_rate,
                evict_rate=self.evict_rate,
                weights=dict(self.violation_weights))
        return self._qpol


#: what ``register_tenant(..., tenant_class=...)`` accepts
ClassSpec = Union[TenantClassPolicy, TenantClass, str]


def as_class_policy(spec: Optional[ClassSpec]
                    ) -> Optional[TenantClassPolicy]:
    """Normalize a class spec: a full policy passes through; a bare
    :class:`TenantClass` (or its string value) gets that class's factory
    defaults; None stays None (the class-less, pre-class behavior)."""
    if spec is None or isinstance(spec, TenantClassPolicy):
        return spec
    if isinstance(spec, str):
        spec = TenantClass(spec)
    if spec is TenantClass.LATENCY_CRITICAL:
        return TenantClassPolicy.latency_critical()
    return TenantClassPolicy.best_effort()
