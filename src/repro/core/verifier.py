"""Static bounds verifier — trace-time proof of arena-access safety.

Guardian (§4.3) fences only *register-addressed* PTX loads because direct
accesses are provably safe at compile time.  The jaxpr sandbox makes the
same static/dynamic split but proves nothing itself: every tainted access
is fenced at runtime.  This module is the missing compiler pass: an
**interval abstract interpretation** over the traced jaxpr that classifies
each tainted access site as

    PROVEN   statically in-bounds w.r.t. the fence row's ``(base, mask)``
             (or the accessed operand's extent) — the runtime fence is
             redundant and :func:`repro.core.sandbox.sandbox` elides it;
    FENCED   unprovable either way — keep the runtime fence (the paper's
             register-addressed case);
    REFUTED  provably out-of-bounds on *every* launch — surfaced at trace
             time as :class:`GuardianStaticViolation` instead of a silent
             runtime clamp.

Abstract domain
---------------
Each value gets one interval ``[lo, hi]`` collapsed over its elements.
Bounds are **affine-symbolic**: integer linear expressions over the fence
row's symbols ``B`` (base) and ``S`` (size) — concrete integers when the
row is static.  Comparisons are decided by minimizing the difference over
the symbol polytope ``{B >= 0, S >= 1, B + S <= N}`` (``N`` = arena
extent when known): a linear function attains its minimum at a vertex, so
three evaluations decide any provable inequality.  This is what lets a
kernel that applies its *own* fence — ``(idx & mask) | base`` with the
row's injected ``(base, mask)`` operands — prove its accesses land in
``[B, B+S-1]`` for every tenant, with no per-partition specialization:
``x & m`` with ``m ∈ [S-1, S-1]`` gives ``[0, S-1]`` and ``x | b`` with
nonnegative operands is bounded by the operand sum.

Loops (``scan`` / ``while`` / ``cond``) are handled by a fixpoint over the
carried taints and intervals with **widening**: after the first unstable
join a changed bound is widened to ±∞, so the iteration always converges
(sites inside the body degrade to FENCED rather than rejecting the
kernel).  The sandbox falls back to rejection only if the fixpoint fails
to converge (:class:`VerifierError`).

The result is a :class:`SandboxProof` — per-site provenance the sandbox
consumes to elide fences, the manager caches alongside its jit caches,
and ``python -m repro.lint`` renders as per-kernel audit tables.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.extend.core as jex_core
import numpy as np

from repro.core.fence import FenceParams
from repro.core.violations import ViolationKind

# --------------------------------------------------------------------------
# Primitive tables shared with the sandbox (the two walkers must classify
# taint identically or site paths would diverge).
# --------------------------------------------------------------------------

#: Primitives through which "this value IS the arena slot space" propagates.
_TAINT_TRANSPARENT = {
    "convert_element_type",
    "copy",
    "reshape",
    "transpose",
    "stop_gradient",
    "reduce_precision",
}

#: Scatter-family primitives: operand 0 is the arena, operand 1 the indices.
_SCATTER_PRIMS = {
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "scatter_add", "scatter_apply",
}

#: Call-like primitives interpreted recursively (jaxpr param name varies).
_CALL_PRIMS = {
    "jit": "jaxpr",
    "pjit": "jaxpr",
    "closed_call": "call_jaxpr",
    "custom_jvp_call": "call_jaxpr",
    "custom_vjp_call": "call_jaxpr",
    "custom_vjp_call_jaxpr": "fun_jaxpr",
    "remat": "jaxpr",
    "checkpoint": "jaxpr",
}

#: Loop/branch primitives with verified structural support.
_LOOP_PRIMS = {"scan", "while", "cond"}


class GuardianStaticViolation(Exception):
    """A tenant kernel contains an access the verifier *refuted*: provably
    out-of-bounds on every launch.  Raised at trace time (registration or
    first compile) with the per-site diagnostic — the static analogue of a
    CHECK-mode detection, caught before the kernel ever runs."""


class VerifierError(Exception):
    """The abstract interpretation could not complete (e.g. a loop-carry
    fixpoint failed to converge).  The sandbox treats this as "fall back
    to rejection": the kernel keeps its runtime fences or is refused."""


class GuardianTaintWarning(UserWarning):
    """A taint-transparent op reshaped away the arena's slot dimension
    (reshape splitting dim 0 / transpose demoting dim 0).  Taint is *kept*
    — downstream accesses stay fenced, which can over-fence value math —
    instead of silently dropping the arena lineage."""


def transparent_taint(name: str, eqn, in_shape) -> bool:
    """Taint rule for :data:`_TAINT_TRANSPARENT` prims with a tainted
    operand 0 — shared between the sandbox and the verifier.

    ``reshape``/``transpose`` that preserve dim 0 keep taint with exact
    slot-space meaning.  When dim 0 is split or demoted the slot lineage
    still flows through the data, so taint is **kept conservatively** and
    a :class:`GuardianTaintWarning` is emitted: downstream dim-0 indexing
    of the reshaped array will be fenced against the row even though the
    leading axis is no longer the slot axis (containment over precision).
    """
    if name == "reshape":
        new = eqn.params.get("new_sizes", None)
        if in_shape and new and in_shape[0] == new[0]:
            return True
        warnings.warn(
            f"reshape {tuple(in_shape)} -> {tuple(new) if new else new} "
            "does not preserve the arena slot dim 0; keeping taint "
            "(downstream accesses stay fenced)", GuardianTaintWarning,
            stacklevel=2)
        return True
    if name == "transpose":
        perm = eqn.params.get("permutation", ())
        if bool(perm) and perm[0] == 0:
            return True
        warnings.warn(
            f"transpose permutation {tuple(perm)} demotes the arena slot "
            "dim 0; keeping taint (downstream accesses stay fenced)",
            GuardianTaintWarning, stacklevel=2)
        return True
    return True


# --------------------------------------------------------------------------
# Linear expressions over bound symbols
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Lin:
    """``const + Σ coef_i · sym_i`` with integer coefficients.

    Symbols are small ints allocated by :class:`SymCtx`; a concrete bound
    is a ``Lin`` with no terms.  Python-int arithmetic — no overflow.
    """

    const: int
    terms: Tuple[Tuple[int, int], ...] = ()   # ((sym_id, coef), ...) sorted

    def __add__(self, other: "Lin") -> "Lin":
        acc = dict(self.terms)
        for s, c in other.terms:
            acc[s] = acc.get(s, 0) + c
        return Lin(self.const + other.const,
                   tuple(sorted((s, c) for s, c in acc.items() if c)))

    def __sub__(self, other: "Lin") -> "Lin":
        return self + other.scale(-1)

    def scale(self, k: int) -> "Lin":
        if k == 0:
            return Lin(0)
        return Lin(self.const * k,
                   tuple((s, c * k) for s, c in self.terms))

    def shift(self, k: int) -> "Lin":
        return Lin(self.const + k, self.terms)

    @property
    def is_const(self) -> bool:
        return not self.terms

    def __str__(self) -> str:
        parts = [str(self.const)] if (self.const or not self.terms) else []
        for s, c in self.terms:
            name = _SYM_NAMES.get(s, f"x{s}")
            parts.append(f"{'+' if c > 0 else '-'}{abs(c) if abs(c) != 1 else ''}{name}")
        out = "".join(parts)
        return out.lstrip("+") or "0"


_SYM_NAMES: Dict[int, str] = {}   # sym_id -> display name (diagnostics only)


def lc(c: int) -> Lin:
    return Lin(int(c))


class SymCtx:
    """Allocates ``(B, S)`` symbol pairs and decides linear inequalities.

    Each pair carries the partition invariants ``B >= 0``, ``S >= 1`` and —
    when the arena extent ``N`` is known — ``B + S <= N``.  A linear
    expression is provably nonnegative iff its minimum over every pair's
    feasible polytope is >= 0; by linearity the pairs contribute
    independently and each contribution is minimized at a polytope vertex.
    """

    def __init__(self):
        self._next = 0
        self._pair_of: Dict[int, Tuple[int, int, Optional[int]]] = {}

    def new_pair(self, extent: Optional[int] = None,
                 tag: str = "") -> Tuple[int, int]:
        b, s = self._next, self._next + 1
        self._next += 2
        self._pair_of[b] = (b, s, extent)
        self._pair_of[s] = (b, s, extent)
        _SYM_NAMES[b] = f"B{tag}"
        _SYM_NAMES[s] = f"S{tag}"
        return b, s

    def prove_nonneg(self, e: Lin) -> bool:
        """Provably ``e >= 0`` for every feasible symbol assignment."""
        by_pair: Dict[int, Tuple[int, int, Optional[int]]] = {}
        coefs: Dict[int, Dict[int, int]] = {}
        for sym, coef in e.terms:
            pair = self._pair_of.get(sym)
            if pair is None:
                return False
            by_pair[pair[0]] = pair
            d = coefs.setdefault(pair[0], {})
            d[sym] = coef
        total = e.const
        for b, (pb, ps, extent) in by_pair.items():
            db = coefs[b].get(pb, 0)
            ds = coefs[b].get(ps, 0)
            if extent is None:
                # B in [0, inf), S in [1, inf): bounded below only when
                # both coefficients are nonnegative (min at B=0, S=1)
                if db < 0 or ds < 0:
                    return False
                total += ds
            else:
                n = int(extent)
                # vertices of {B>=0, S>=1, B+S<=N}
                total += min(db * 0 + ds * 1,
                             db * 0 + ds * n,
                             db * max(n - 1, 0) + ds * 1)
        return total >= 0

    def le(self, a: Lin, b: Lin) -> bool:
        return self.prove_nonneg(b - a)

    def lt(self, a: Lin, b: Lin) -> bool:
        return self.prove_nonneg((b - a).shift(-1))


# --------------------------------------------------------------------------
# Intervals
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ival:
    """Array-wide interval; ``None`` bound = unbounded in that direction."""

    lo: Optional[Lin] = None
    hi: Optional[Lin] = None

    @property
    def is_top(self) -> bool:
        return self.lo is None and self.hi is None

    def __str__(self) -> str:
        lo = "-inf" if self.lo is None else str(self.lo)
        hi = "+inf" if self.hi is None else str(self.hi)
        return f"[{lo}, {hi}]"


TOP = Ival()


def iconst(lo: int, hi: Optional[int] = None) -> Ival:
    return Ival(lc(lo), lc(lo if hi is None else hi))


def _opt_add(a: Optional[Lin], b: Optional[Lin]) -> Optional[Lin]:
    return None if (a is None or b is None) else a + b


def iadd(a: Ival, b: Ival) -> Ival:
    return Ival(_opt_add(a.lo, b.lo), _opt_add(a.hi, b.hi))


def ineg(a: Ival) -> Ival:
    return Ival(None if a.hi is None else a.hi.scale(-1),
                None if a.lo is None else a.lo.scale(-1))


def isub(a: Ival, b: Ival) -> Ival:
    return iadd(a, ineg(b))


def _as_const(a: Ival) -> Optional[int]:
    """The single concrete value of a degenerate constant interval."""
    if (a.lo is not None and a.hi is not None
            and a.lo.is_const and a.hi.is_const
            and a.lo.const == a.hi.const):
        return a.lo.const
    return None


def imul(a: Ival, b: Ival) -> Ival:
    for x, y in ((a, b), (b, a)):
        k = _as_const(x)
        if k is not None:
            if k >= 0:
                return Ival(None if y.lo is None else y.lo.scale(k),
                            None if y.hi is None else y.hi.scale(k))
            return Ival(None if y.hi is None else y.hi.scale(k),
                        None if y.lo is None else y.lo.scale(k))
    # const-bounded × const-bounded: classic four-products
    bounds = (a.lo, a.hi, b.lo, b.hi)
    if all(x is not None and x.is_const for x in bounds):
        prods = [a.lo.const * b.lo.const, a.lo.const * b.hi.const,
                 a.hi.const * b.lo.const, a.hi.const * b.hi.const]
        return iconst(min(prods), max(prods))
    return TOP


def _pick_le(ctx: SymCtx, a: Optional[Lin], b: Optional[Lin],
             prefer_first: bool = True) -> Optional[Lin]:
    """The provably-smaller of two bounds (None = unknown/incomparable)."""
    if a is None or b is None:
        return None
    if ctx.le(a, b):
        return a
    if ctx.le(b, a):
        return b
    return a if prefer_first else None


def imin(ctx: SymCtx, a: Ival, b: Ival) -> Ival:
    # hi: min(x, y) <= x and <= y, so either hi is sound; prefer provable
    if a.hi is None:
        hi = b.hi
    elif b.hi is None:
        hi = a.hi
    else:
        hi = a.hi if ctx.le(a.hi, b.hi) else \
            (b.hi if ctx.le(b.hi, a.hi) else a.hi)
    # lo: need a bound <= both operands' minima
    lo = _pick_le(ctx, a.lo, b.lo, prefer_first=False)
    return Ival(lo, hi)


def imax(ctx: SymCtx, a: Ival, b: Ival) -> Ival:
    return ineg(imin(ctx, ineg(a), ineg(b)))


def ijoin(ctx: SymCtx, a: Ival, b: Ival) -> Ival:
    lo = _pick_le(ctx, a.lo, b.lo, prefer_first=False)
    hi = None
    if a.hi is not None and b.hi is not None:
        if ctx.le(a.hi, b.hi):
            hi = b.hi
        elif ctx.le(b.hi, a.hi):
            hi = a.hi
    return Ival(lo, hi)


def iwiden(ctx: SymCtx, old: Ival, new: Ival) -> Ival:
    """Classic widening: a bound that moved outward goes to ±∞."""
    lo = old.lo if (old.lo is not None and new.lo is not None
                    and ctx.le(old.lo, new.lo)) else None
    hi = old.hi if (old.hi is not None and new.hi is not None
                    and ctx.le(new.hi, old.hi)) else None
    return Ival(lo, hi)


def ieq(a: Ival, b: Ival) -> bool:
    return a.lo == b.lo and a.hi == b.hi


# --------------------------------------------------------------------------
# Proof artifacts
# --------------------------------------------------------------------------

PROVEN = "proven"
FENCED = "fenced"
REFUTED = "refuted"


@dataclasses.dataclass(frozen=True)
class SiteRecord:
    """One tainted access site: where it is, what it does, what we know."""

    path: Tuple                      # eqn-index path into the jaxpr forest
    kind: ViolationKind              # GATHER / SCATTER / SLICE / UPDATE
    prim: str                        # primitive name at the site
    verdict: str                     # PROVEN | FENCED | REFUTED
    interval: str                    # index interval at the site (display)
    target: str                      # the bound it was classified against
    why: str                         # one-line reason

    def row(self) -> str:
        return (f"{self.verdict.upper():8s} {self.kind.name.lower():8s} "
                f"{self.prim:22s} idx∈{self.interval:24s} "
                f"target {self.target:18s} {self.why}")


@dataclasses.dataclass(frozen=True)
class SandboxProof:
    """The verifier's per-site provenance for one traced kernel.

    ``symbolic=True`` means the proof was computed against the symbolic
    row ``(B, S)`` under the partition invariants — it holds for *every*
    tenant/partition, so the manager may route the kernel like a trusted
    row.  A static proof holds only for the concrete ``(base, size)`` it
    was computed with.
    """

    sites: Tuple[SiteRecord, ...]
    mode: str                        # "row" | "extent"
    symbolic: bool
    arg_sig: Tuple                   # invar (shape, dtype) signature
    n_eqns: int = 0

    @property
    def n_proven(self) -> int:
        return sum(1 for s in self.sites if s.verdict == PROVEN)

    @property
    def n_fenced(self) -> int:
        return sum(1 for s in self.sites if s.verdict == FENCED)

    @property
    def n_refuted(self) -> int:
        return sum(1 for s in self.sites if s.verdict == REFUTED)

    @property
    def fully_proven(self) -> bool:
        """Every site proven (vacuously true for zero dynamic sites)."""
        return self.n_fenced == 0 and self.n_refuted == 0

    @property
    def proven_fraction(self) -> float:
        return self.n_proven / len(self.sites) if self.sites else 1.0

    def verdict_of(self, path: Tuple) -> Optional[str]:
        for s in self.sites:
            if s.path == path:
                return s.verdict
        return None

    def refuted_sites(self) -> Tuple[SiteRecord, ...]:
        return tuple(s for s in self.sites if s.verdict == REFUTED)

    def summary(self) -> Dict[str, Any]:
        return {
            "sites": len(self.sites),
            "proven": self.n_proven,
            "fenced": self.n_fenced,
            "refuted": self.n_refuted,
            "fully_proven": self.fully_proven,
            "proven_fraction": round(self.proven_fraction, 4),
            "symbolic": self.symbolic,
            "mode": self.mode,
        }

    def format_table(self, indent: str = "  ") -> str:
        if not self.sites:
            return indent + "(no dynamic arena access sites)"
        return "\n".join(indent + s.row() for s in self.sites)


# --------------------------------------------------------------------------
# Abstract interpreter
# --------------------------------------------------------------------------

_MAX_FIX_ITERS = 16    # hard convergence guard (widening converges in ~3)


def _aval_of(v):
    return v.aval


def _const_ival(val) -> Ival:
    try:
        arr = np.asarray(val)
    except Exception:
        return TOP
    if arr.size == 0:
        return TOP
    if arr.dtype == np.bool_:
        return iconst(int(arr.min()), int(arr.max()))
    if np.issubdtype(arr.dtype, np.integer):
        return iconst(int(arr.min()), int(arr.max()))
    return TOP


def _int_dtype(aval) -> bool:
    try:
        return (np.issubdtype(aval.dtype, np.integer)
                or aval.dtype == np.bool_)
    except Exception:
        return False


class _AbsState:
    """Verifier walk state: symbol context, site sink, eqn counter."""

    def __init__(self, ctx: SymCtx, target: "_Target"):
        self.ctx = ctx
        self.target = target
        self.sites: List[SiteRecord] = []
        self.n_eqns = 0


@dataclasses.dataclass
class _Target:
    """What "in-bounds" means for this verification.

    ``row`` mode: the fence row ``[row_lo, row_hi]`` (site indices must
    land inside the partition; outside on every launch = REFUTED).
    ``extent`` mode: the accessed operand's own dim-0 extent plus any
    *admissible ranges* — declared guard partitions found in the kernel's
    operands — and refutation is never issued (a trusted step's safety
    property is "every dynamic access is fenced by a declared guard").
    """

    mode: str                              # "row" | "extent"
    row_lo: Optional[Lin] = None
    row_hi: Optional[Lin] = None           # inclusive
    admissible: Tuple[Tuple[Lin, Lin], ...] = ()

    def targets_for(self, extent: Optional[int],
                    length: int = 1) -> List[Tuple[Lin, Lin, str]]:
        """Candidate inclusive [lo, hi] ranges a start/index may occupy.
        ``length`` shrinks the hi for slice starts (start+len-1 <= hi)."""
        out: List[Tuple[Lin, Lin, str]] = []
        if self.mode == "row":
            out.append((self.row_lo, self.row_hi.shift(1 - length),
                        f"[{self.row_lo}, {self.row_hi}]"))
            return out
        if extent is not None:
            out.append((lc(0), lc(extent - length),
                        f"extent[0, {extent - 1}]"))
        for lo, hi in self.admissible:
            out.append((lo, hi.shift(1 - length), f"guard[{lo}, {hi}]"))
        return out


def _classify(state: _AbsState, path: Tuple, kind: ViolationKind,
              prim: str, idx_ival: Ival, extent: Optional[int],
              length: int = 1) -> str:
    """PROVEN / FENCED / REFUTED for one access site, recorded in-place."""
    ctx = state.ctx
    tgt = state.target
    targets = tgt.targets_for(extent, length)
    verdict, why, tdesc = FENCED, "interval not contained", "-"
    for lo, hi, desc in targets:
        tdesc = desc
        if (idx_ival.lo is not None and idx_ival.hi is not None
                and ctx.le(lo, idx_ival.lo) and ctx.le(idx_ival.hi, hi)):
            verdict, why = PROVEN, "statically contained"
            break
    if verdict is FENCED and tgt.mode == "row":
        # refutation: the runtime CHECK predicate is on the raw index /
        # start scalar (base <= idx < base+size, length-independent), so
        # refute against the full row — "always trips CHECK" is exact
        lo, hi, tdesc0 = tgt.targets_for(None, 1)[0]
        if idx_ival.hi is not None and ctx.lt(idx_ival.hi, lo):
            verdict, why, tdesc = REFUTED, "always below partition", tdesc0
        elif idx_ival.lo is not None and ctx.lt(hi, idx_ival.lo):
            verdict, why, tdesc = REFUTED, "always above partition", tdesc0
        else:
            tdesc = tdesc0
            why = ("interval unbounded" if idx_ival.is_top
                   else "interval straddles bound")
    elif verdict is FENCED:
        why = ("interval unbounded" if idx_ival.is_top
               else "interval straddles bound")
    state.sites.append(SiteRecord(
        path=path, kind=kind, prim=prim, verdict=verdict,
        interval=str(idx_ival), target=tdesc, why=why))
    return verdict


def _abs_eval_prim(state: _AbsState, eqn, ivals: List[Ival],
                   avals: List[Any]) -> List[Ival]:
    """Interval transfer function for one first-order primitive."""
    ctx = state.ctx
    name = eqn.primitive.name
    n_out = len(eqn.outvars)

    def one(v: Ival) -> List[Ival]:
        return [v] * n_out

    if name == "iota":
        dim = eqn.params.get("dimension", 0)
        shape = eqn.params.get("shape", ())
        n = shape[dim] if shape else 1
        return one(iconst(0, max(int(n) - 1, 0)))
    if name in ("argmax", "argmin"):
        axes = eqn.params.get("axes", (0,))
        n = avals[0].shape[axes[0]] if avals[0].shape else 1
        return one(iconst(0, max(int(n) - 1, 0)))
    if name in ("copy", "broadcast_in_dim", "reshape", "transpose",
                "squeeze", "rev", "slice", "stop_gradient",
                "reduce_precision", "reduce_min", "reduce_max",
                "expand_dims"):
        return one(ivals[0])
    if name == "sort":
        # k-th output is a permutation of the k-th operand's elements
        return [ivals[k] if k < len(ivals) else TOP for k in range(n_out)]
    if name == "convert_element_type":
        out_aval = eqn.outvars[0].aval
        if _int_dtype(out_aval) and _int_dtype(avals[0]):
            return one(ivals[0])
        return one(TOP)
    if name == "add":
        return one(iadd(ivals[0], ivals[1]))
    if name == "sub":
        return one(isub(ivals[0], ivals[1]))
    if name == "neg":
        return one(ineg(ivals[0]))
    if name == "mul":
        return one(imul(ivals[0], ivals[1]))
    if name == "max":
        return one(imax(ctx, ivals[0], ivals[1]))
    if name == "min":
        return one(imin(ctx, ivals[0], ivals[1]))
    if name == "clamp":
        # clamp(lo, x, hi) = min(max(x, lo), hi)
        return one(imin(ctx, imax(ctx, ivals[1], ivals[0]), ivals[2]))
    if name == "abs":
        v = ivals[0]
        if v.lo is not None and ctx.prove_nonneg(v.lo):
            return one(v)
        return one(Ival(lc(0), None))
    if name == "rem":
        d = _as_const(ivals[1])
        if d is not None and d > 0:
            v = ivals[0]
            if v.lo is not None and ctx.prove_nonneg(v.lo):
                hi = lc(d - 1)
                if v.hi is not None and ctx.le(v.hi, hi):
                    hi = v.hi          # |rem| <= |dividend|
                return one(Ival(lc(0), hi))
            return one(iconst(-(d - 1), d - 1))
        return one(TOP)
    if name == "div":
        d = _as_const(ivals[1])
        v = ivals[0]
        if (d is not None and d > 0 and v.lo is not None
                and v.hi is not None and v.lo.is_const and v.hi.is_const
                and v.lo.const >= 0):
            return one(iconst(v.lo.const // d, v.hi.const // d))
        return one(TOP)
    if name in ("shift_right_logical", "shift_right_arithmetic"):
        k = _as_const(ivals[1])
        v = ivals[0]
        if (k is not None and k >= 0 and v.lo is not None
                and v.hi is not None and v.lo.is_const and v.hi.is_const
                and v.lo.const >= 0):
            return one(iconst(v.lo.const >> k, v.hi.const >> k))
        return one(TOP)
    if name == "and":
        # x & m ∈ [0, hi(m)] when m >= 0 (two's complement)
        cands = []
        for i in (0, 1):
            v = ivals[i]
            if v.lo is not None and ctx.prove_nonneg(v.lo):
                cands.append(v.hi)
        if not cands:
            return one(TOP)
        hi = cands[0]
        for c in cands[1:]:
            hi = _pick_le(ctx, hi, c) if hi is not None else c
        return one(Ival(lc(0), hi))
    if name in ("or", "xor"):
        a, b = ivals[0], ivals[1]
        if (a.lo is not None and ctx.prove_nonneg(a.lo)
                and b.lo is not None and ctx.prove_nonneg(b.lo)):
            # for nonneg x, y: max(x, y) <= x|y <= x + y (x^y likewise)
            lo = lc(0)
            if name == "or":
                lo = a.lo if ctx.le(b.lo, a.lo) else b.lo
            hi = _opt_add(a.hi, b.hi)
            return one(Ival(lo, hi))
        return one(TOP)
    if name == "select_n":
        # decided predicate (e.g. jnp.take's negative-index wrap where the
        # index is provably nonnegative) -> only the taken case flows
        k = _as_const(ivals[0])
        if k is not None and 0 <= k < len(ivals) - 1:
            return one(ivals[1 + k])
        out = ivals[1]
        for v in ivals[2:]:
            out = ijoin(ctx, out, v)
        return one(out)
    if name == "concatenate":
        out = ivals[0]
        for v in ivals[1:]:
            out = ijoin(ctx, out, v)
        return one(out)
    if name == "pad":
        return one(ijoin(ctx, ivals[0], ivals[1]))
    if name == "gather":
        return one(ivals[0])               # values come from the operand
    if name in _SCATTER_PRIMS:
        return one(ijoin(ctx, ivals[0], ivals[2] if len(ivals) > 2
                         else TOP))
    if name == "dynamic_slice":
        return one(ivals[0])
    if name == "dynamic_update_slice":
        return one(ijoin(ctx, ivals[0], ivals[1]))
    if name in ("lt", "le", "gt", "ge"):
        a, b = ivals[0], ivals[1]
        if name in ("gt", "ge"):           # a > b  ==  b < a
            a, b = b, a
            name = {"gt": "lt", "ge": "le"}[name]
        strict = name == "lt"
        if a.hi is not None and b.lo is not None and (
                ctx.lt(a.hi, b.lo) if strict else ctx.le(a.hi, b.lo)):
            return one(iconst(1))          # always true
        if a.lo is not None and b.hi is not None and (
                ctx.le(b.hi, a.lo) if strict else ctx.lt(b.hi, a.lo)):
            return one(iconst(0))          # always false
        return one(iconst(0, 1))
    if name in ("eq", "ne", "not", "is_finite"):
        return one(iconst(0, 1))
    return [TOP] * n_out


def _abs_interpret(
    state: _AbsState,
    closed: Any,
    in_taints: Sequence[bool],
    in_ivals: Sequence[Ival],
    path: Tuple = (),
    record: bool = True,
) -> Tuple[List[bool], List[Ival]]:
    """Walk one (Closed)Jaxpr abstractly; returns output (taints, ivals).

    ``record=False`` runs the walk purely for its transfer functions (the
    loop-fixpoint iterations) without emitting site records or counting
    eqns twice.
    """
    jaxpr = closed.jaxpr
    taint: Dict[Any, bool] = {}
    ival: Dict[Any, Ival] = {}

    for var, val in zip(jaxpr.constvars, closed.consts):
        taint[var] = False
        ival[var] = _const_ival(val)
    for var, t, v in zip(jaxpr.invars, in_taints, in_ivals):
        taint[var] = t
        ival[var] = v

    def read_t(v) -> bool:
        if isinstance(v, jex_core.Literal):
            return False
        return taint.get(v, False)

    def read_i(v) -> Ival:
        if isinstance(v, jex_core.Literal):
            return _const_ival(v.val)
        return ival.get(v, TOP)

    for i, eqn in enumerate(jaxpr.eqns):
        if record:
            state.n_eqns += 1
        name = eqn.primitive.name
        ts = [read_t(v) for v in eqn.invars]
        vs = [read_i(v) for v in eqn.invars]
        avals = [_aval_of(v) for v in eqn.invars]
        site_path = (*path, i)

        if name in _CALL_PRIMS:
            sub = eqn.params.get(_CALL_PRIMS[name])
            if sub is None:
                sub = next(v for v in eqn.params.values()
                           if hasattr(v, "jaxpr"))
            out_ts, out_vs = _abs_interpret(state, sub, ts, vs,
                                            path=site_path, record=record)
            for var, t, v in zip(eqn.outvars, out_ts, out_vs):
                taint[var] = t
                ival[var] = v
            continue

        if name in _LOOP_PRIMS and any(ts):
            out_ts, out_vs = _abs_loop(state, eqn, ts, vs,
                                       path=site_path, record=record)
            for var, t, v in zip(eqn.outvars, out_ts, out_vs):
                taint[var] = t
                ival[var] = v
            continue

        out_taint = False

        if name == "gather" and ts[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in enumerate(dnums.start_index_map) if d == 0]
            if cols and record:
                _classify(state, site_path, ViolationKind.GATHER, name,
                          vs[1], int(avals[0].shape[0])
                          if avals[0].shape else None)
            out_taint = False
        elif name in _SCATTER_PRIMS and ts[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in
                    enumerate(dnums.scatter_dims_to_operand_dims) if d == 0]
            if cols and record:
                _classify(state, site_path, ViolationKind.SCATTER, name,
                          vs[1], int(avals[0].shape[0])
                          if avals[0].shape else None)
            out_taint = True
        elif name == "dynamic_slice" and ts[0]:
            if record:
                sizes = eqn.params["slice_sizes"]
                _classify(state, site_path, ViolationKind.SLICE, name,
                          vs[1], int(avals[0].shape[0])
                          if avals[0].shape else None,
                          length=int(sizes[0]))
            out_taint = False
        elif name == "dynamic_update_slice" and ts[0]:
            if record:
                upd = avals[1].shape[0] if avals[1].shape else 1
                _classify(state, site_path, ViolationKind.UPDATE, name,
                          vs[2], int(avals[0].shape[0])
                          if avals[0].shape else None,
                          length=int(upd))
            out_taint = True
        elif name in _TAINT_TRANSPARENT and ts[0]:
            with warnings.catch_warnings():
                if not record:   # warn once, on the recording pass
                    warnings.simplefilter("ignore", GuardianTaintWarning)
                out_taint = transparent_taint(name, eqn, avals[0].shape)

        out_ivals = _abs_eval_prim(state, eqn, vs, avals)
        for var, v in zip(eqn.outvars, out_ivals):
            taint[var] = out_taint
            ival[var] = v

    out_ts = [read_t(v) for v in jaxpr.outvars]
    out_vs = [read_i(v) for v in jaxpr.outvars]
    return out_ts, out_vs


def _fixpoint(state: _AbsState, body: Any, n_pre: int, n_carry: int,
              pre_ts, pre_vs, carry_ts, carry_vs, xs_ts, xs_vs,
              path: Tuple) -> Tuple[List[bool], List[Ival],
                                    List[bool], List[Ival]]:
    """Taint + interval fixpoint with widening over a loop body.

    ``pre`` are the consts (never updated), ``carry`` the loop-carried
    values, ``xs`` per-iteration slices (scan only; empty for while).
    Returns converged (carry_ts, carry_vs) and the body's full output
    (taints, ivals) at the fixpoint.
    """
    ctx = state.ctx
    carry_ts = list(carry_ts)
    carry_vs = list(carry_vs)
    for it in range(_MAX_FIX_ITERS):
        out_ts, out_vs = _abs_interpret(
            state, body, [*pre_ts, *carry_ts, *xs_ts],
            [*pre_vs, *carry_vs, *xs_vs], path=path, record=False)
        new_ts = [a or b for a, b in zip(carry_ts, out_ts[:n_carry])]
        new_vs = [ijoin(ctx, a, b)
                  for a, b in zip(carry_vs, out_vs[:n_carry])]
        if it >= 1:
            new_vs = [iwiden(ctx, old, new)
                      for old, new in zip(carry_vs, new_vs)]
        if new_ts == carry_ts and all(
                ieq(a, b) for a, b in zip(new_vs, carry_vs)):
            return carry_ts, carry_vs, out_ts, out_vs
        carry_ts, carry_vs = new_ts, new_vs
    raise VerifierError(
        f"loop-carry interval fixpoint did not converge at path {path} "
        f"after {_MAX_FIX_ITERS} iterations")


def loop_carry_taints(eqn, in_taints: Sequence[bool]) -> Tuple[List[bool],
                                                               List[bool]]:
    """Converged (carry taints, body output taints) for a tainted
    ``scan``/``while`` eqn — the sandbox uses this to interpret loop
    bodies with stable taint assignments.  For ``while`` the "body output
    taints" cover the carry only."""
    state = _AbsState(SymCtx(), _Target(mode="extent"))
    name = eqn.primitive.name
    if name == "scan":
        body = eqn.params["jaxpr"]
        n_c = eqn.params["num_consts"]
        n_car = eqn.params["num_carry"]
        pre_ts = list(in_taints[:n_c])
        car_ts = list(in_taints[n_c:n_c + n_car])
        xs_ts = list(in_taints[n_c + n_car:])
        n_in = len(body.jaxpr.invars)
        tops = [TOP] * n_in
        car_ts, _, out_ts, _ = _fixpoint(
            state, body, n_c, n_car, pre_ts, tops[:n_c], car_ts,
            tops[:n_car], xs_ts, tops[:len(xs_ts)], path=())
        return car_ts, out_ts
    if name == "while":
        body = eqn.params["body_jaxpr"]
        n_cc = eqn.params["cond_nconsts"]
        n_bc = eqn.params["body_nconsts"]
        pre_ts = list(in_taints[n_cc:n_cc + n_bc])
        car_ts = list(in_taints[n_cc + n_bc:])
        n_car = len(car_ts)
        tops_pre = [TOP] * n_bc
        tops_car = [TOP] * n_car
        car_ts, _, out_ts, _ = _fixpoint(
            state, body, n_bc, n_car, pre_ts, tops_pre, car_ts, tops_car,
            [], [], path=())
        return car_ts, out_ts
    raise ValueError(name)


def _abs_loop(state: _AbsState, eqn, ts, vs, path: Tuple,
              record: bool) -> Tuple[List[bool], List[Ival]]:
    """Abstract scan/while/cond with a widened carry fixpoint."""
    name = eqn.primitive.name
    if name == "scan":
        body = eqn.params["jaxpr"]
        n_c = eqn.params["num_consts"]
        n_car = eqn.params["num_carry"]
        pre_ts, pre_vs = ts[:n_c], vs[:n_c]
        car_ts, car_vs = ts[n_c:n_c + n_car], vs[n_c:n_c + n_car]
        xs_ts, xs_vs = ts[n_c + n_car:], vs[n_c + n_car:]
        car_ts, car_vs, out_ts, out_vs = _fixpoint(
            state, body, n_c, n_car, pre_ts, pre_vs, car_ts, car_vs,
            xs_ts, xs_vs, (*path, 0))
        if record:   # one recording pass at the fixpoint
            out_ts, out_vs = _abs_interpret(
                state, body, [*pre_ts, *car_ts, *xs_ts],
                [*pre_vs, *car_vs, *xs_vs], path=(*path, 0), record=True)
        # outputs: final carry then stacked ys
        return ([*car_ts, *out_ts[n_car:]],
                [*car_vs, *out_vs[n_car:]])
    if name == "while":
        cond = eqn.params["cond_jaxpr"]
        body = eqn.params["body_jaxpr"]
        n_cc = eqn.params["cond_nconsts"]
        n_bc = eqn.params["body_nconsts"]
        cpre_ts, cpre_vs = ts[:n_cc], vs[:n_cc]
        bpre_ts, bpre_vs = ts[n_cc:n_cc + n_bc], vs[n_cc:n_cc + n_bc]
        car_ts, car_vs = ts[n_cc + n_bc:], vs[n_cc + n_bc:]
        n_car = len(car_ts)
        car_ts, car_vs, _, _ = _fixpoint(
            state, body, n_bc, n_car, bpre_ts, bpre_vs, car_ts, car_vs,
            [], [], (*path, 1))
        if record:
            _abs_interpret(state, cond, [*cpre_ts, *car_ts],
                           [*cpre_vs, *car_vs], path=(*path, 0),
                           record=True)
            _abs_interpret(state, body, [*bpre_ts, *car_ts],
                           [*bpre_vs, *car_vs], path=(*path, 1),
                           record=True)
        return list(car_ts), list(car_vs)
    if name == "cond":
        branches = eqn.params["branches"]
        op_ts, op_vs = ts[1:], vs[1:]
        out_ts: Optional[List[bool]] = None
        out_vs: Optional[List[Ival]] = None
        for b, br in enumerate(branches):
            bts, bvs = _abs_interpret(state, br, op_ts, op_vs,
                                      path=(*path, b), record=record)
            if out_ts is None:
                out_ts, out_vs = bts, bvs
            else:
                out_ts = [a or b_ for a, b_ in zip(out_ts, bts)]
                out_vs = [ijoin(state.ctx, a, b_)
                          for a, b_ in zip(out_vs, bvs)]
        return out_ts or [], out_vs or []
    raise ValueError(name)


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def _invar_sig(closed) -> Tuple:
    return tuple((tuple(v.aval.shape), str(v.aval.dtype))
                 for v in closed.jaxpr.invars)


def verify_jaxpr(
    closed: Any,
    in_taints: Sequence[bool],
    params: Optional[FenceParams] = None,
    *,
    in_roles: Optional[Sequence[Optional[str]]] = None,
    arena_extent: Optional[int] = None,
    mode: str = "row",
    admissible: Sequence[Tuple[int, int]] = (),
    dyn_role_pairs: Optional[Dict[int, Tuple[str, int]]] = None,
) -> SandboxProof:
    """Run the bounds proof over an already-traced ClosedJaxpr.

    ``in_taints`` flags arena-derived invars (one per flat invar).
    ``params`` is the fence row: static ints give a concrete proof,
    ``None``/traced gives the symbolic-row proof (valid for every
    partition of an arena with ``arena_extent`` slots).  ``in_roles``
    optionally names invars that *carry the row into the kernel* —
    ``"base"`` / ``"mask"`` / ``"size"`` — the paper's two injected
    parameters; their intervals become the row symbols, which is what
    lets an internally-fenced kernel prove itself.

    ``mode="extent"`` verifies trusted steps: sites must fit the accessed
    operand's extent or one of the ``admissible`` static guard ranges
    (``dyn_role_pairs`` maps flat-invar index -> (field, pair_no) for
    dynamic guard params, each pair getting its own symbols).
    """
    ctx = SymCtx()
    static = params is not None and params.is_static

    if mode == "row":
        if static:
            row_lo = lc(params.base)
            row_hi = lc(params.base + params.size - 1)
            base_iv = iconst(params.base)
            size_iv = iconst(params.size)
        else:
            b, s = ctx.new_pair(extent=arena_extent)
            row_lo = Lin(0, ((b, 1),))
            row_hi = Lin(-1, ((b, 1), (s, 1)))
            base_iv = Ival(row_lo, row_lo)
            size_iv = Ival(Lin(0, ((s, 1),)), Lin(0, ((s, 1),)))
        target = _Target(mode="row", row_lo=row_lo, row_hi=row_hi)
        role_ivals = {
            "base": base_iv,
            "size": size_iv,
            "mask": Ival(size_iv.lo.shift(-1), size_iv.hi.shift(-1)),
        }
    else:
        adm: List[Tuple[Lin, Lin]] = [
            (lc(b0), lc(b0 + s0 - 1)) for b0, s0 in admissible]
        pair_syms: Dict[int, Tuple[int, int]] = {}
        for pos, (field, pno) in (dyn_role_pairs or {}).items():
            if pno not in pair_syms:
                pair_syms[pno] = ctx.new_pair(tag=str(pno))
                b, s = pair_syms[pno]
                adm.append((Lin(0, ((b, 1),)),
                            Lin(-1, ((b, 1), (s, 1)))))
        target = _Target(mode="extent", admissible=tuple(adm))
        role_ivals = {}

    n_in = len(closed.jaxpr.invars)
    in_ivals: List[Ival] = [TOP] * n_in
    if mode == "row" and in_roles is not None:
        for i, role in enumerate(in_roles):
            if role in role_ivals:
                in_ivals[i] = role_ivals[role]
    if mode == "extent" and dyn_role_pairs:
        for pos, (field, pno) in dyn_role_pairs.items():
            b, s = pair_syms[pno]
            bl = Lin(0, ((b, 1),))
            sl = Lin(0, ((s, 1),))
            if field == "base":
                in_ivals[pos] = Ival(bl, bl)
            elif field == "size":
                in_ivals[pos] = Ival(sl, sl)
            elif field == "mask":
                in_ivals[pos] = Ival(sl.shift(-1), sl.shift(-1))

    state = _AbsState(ctx, target)
    taints = list(in_taints)
    if len(taints) != n_in:
        raise VerifierError(
            f"taint vector length {len(taints)} != {n_in} invars")
    _abs_interpret(state, closed, taints, in_ivals, path=(), record=True)
    return SandboxProof(
        sites=tuple(state.sites), mode=mode,
        symbolic=(mode == "row" and not static),
        arg_sig=_invar_sig(closed), n_eqns=state.n_eqns)


def _split_dyn(example_args: Sequence[Any]):
    """The sandbox's static/dynamic arg split, shared here so standalone
    verification traces the kernel identically."""
    dyn_pos = [i for i, a in enumerate(example_args)
               if isinstance(a, (jax.Array, np.ndarray))
               or isinstance(a, jax.core.Tracer)
               or isinstance(a, jax.ShapeDtypeStruct)
               or (jax.tree_util.tree_leaves(a)
                   and not isinstance(a, (bool, int, float, complex, str,
                                          bytes)))]
    dyn_args = [example_args[p] for p in dyn_pos]
    return dyn_pos, dyn_args


def trace_kernel(fn: Callable, example_args: Sequence[Any],
                 arena_argnums: Sequence[int] = (0,)):
    """``(closed_jaxpr, flat_taints, leaf_slots)`` for a kernel traced the
    way :func:`repro.core.sandbox.sandbox` traces it.  ``leaf_slots`` maps
    each original arg position to its (start, stop) flat-leaf range."""
    example_args = tuple(example_args)
    dyn_pos, dyn_args = _split_dyn(example_args)

    def fn_dyn(*dargs):
        full = list(example_args)
        for p, v in zip(dyn_pos, dargs):
            full[p] = v
        return fn(*full)

    closed = jax.make_jaxpr(fn_dyn)(*dyn_args)
    arena_set = frozenset(arena_argnums)
    taints: List[bool] = []
    leaf_slots: Dict[int, Tuple[int, int]] = {}
    off = 0
    for p, a in zip(dyn_pos, dyn_args):
        n = len(jax.tree_util.tree_leaves(a))
        leaf_slots[p] = (off, off + n)
        taints.extend([p in arena_set] * n)
        off += n
    return closed, taints, leaf_slots


def verify(
    fn: Callable,
    example_args: Sequence[Any],
    arena_argnums: Sequence[int] = (0,),
    bound_argnums: Sequence[int] = (),
    params: Optional[FenceParams] = None,
    mode: str = "row",
) -> SandboxProof:
    """Standalone bounds proof for ``fn(*example_args)``.

    ``bound_argnums`` names the two injected row parameters —
    ``(base_argnum, mask_argnum)`` — the launch path guarantees carry the
    fence row (Guardian's Listing-1 augmentation).  ``mode="extent"``
    additionally scans the operands for :class:`FenceParams` (GuardSpec
    leaves) and admits their declared partitions as proof targets.
    """
    closed, taints, leaf_slots = trace_kernel(fn, example_args,
                                              arena_argnums)
    n_in = len(closed.jaxpr.invars)

    in_roles: List[Optional[str]] = [None] * n_in
    for role, argnum in zip(("base", "mask"), bound_argnums):
        slot = leaf_slots.get(argnum)
        if slot is not None and slot[1] - slot[0] == 1:
            in_roles[slot[0]] = role

    arena_extent = None
    for i, t in enumerate(taints):
        if t and closed.jaxpr.invars[i].aval.shape:
            arena_extent = int(closed.jaxpr.invars[i].aval.shape[0])
            break

    admissible: List[Tuple[int, int]] = []
    dyn_role_pairs: Dict[int, Tuple[str, int]] = {}
    if mode == "extent":
        dyn_pos, dyn_args = _split_dyn(tuple(example_args))
        pair_no = 0
        for p, a in zip(dyn_pos, dyn_args):
            start, _stop = leaf_slots[p]
            nodes, _ = jax.tree_util.tree_flatten(
                a, is_leaf=lambda x: isinstance(x, FenceParams))
            off = start
            for node in nodes:
                if isinstance(node, FenceParams):
                    # array-valued fields are this node's pytree leaves,
                    # in field order (fence._fence_params_flatten)
                    is_dyn = _fp_aux(node)
                    dyn_fields = [f for f, d in zip(
                        ("base", "size", "magic_m", "magic_s"), is_dyn)
                        if d]
                    if node.is_static:
                        admissible.append((int(node.base), int(node.size)))
                    elif dyn_fields:
                        for j, f in enumerate(dyn_fields):
                            if f in ("base", "size"):
                                dyn_role_pairs[off + j] = (f, pair_no)
                        pair_no += 1
                    off += len(dyn_fields)
                else:
                    off += len(jax.tree_util.tree_leaves(node))

    return verify_jaxpr(
        closed, taints, params, in_roles=in_roles,
        arena_extent=arena_extent, mode=mode,
        admissible=admissible, dyn_role_pairs=dyn_role_pairs)


def _fp_aux(node: FenceParams):
    """is_dyn flags of a FenceParams' fields, in field order."""
    vals = (node.base, node.size, node.magic_m, node.magic_s)
    return tuple(isinstance(v, (jax.Array, np.ndarray)) for v in vals)


def refute_message(proof: SandboxProof, name: str = "<kernel>") -> str:
    lines = [f"kernel {name!r}: {proof.n_refuted} access site(s) are "
             "provably out-of-bounds on every launch:"]
    for s in proof.refuted_sites():
        lines.append("  " + s.row())
    lines.append("(the verifier refuses at trace time; fix the index "
                 "computation or register with verify=False to fall back "
                 "to runtime containment)")
    return "\n".join(lines)
