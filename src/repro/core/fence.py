"""Bounds fencing / checking primitives — Guardian §4.4 ("Bounds Checking
Tradeoffs"), adapted to TPU index spaces.

The paper instruments every PTX load/store with one of three bounds modes.
On TPU the analogous *dynamically computed address* is a data-dependent
integer index into a shared HBM arena (KV page ids, embedding rows, expert
offsets, state slots).  The fence is applied to the index *before* it is used
by a gather/scatter/DMA — the exact analogue of patching the PTX register
before ``ld.global``.

Three modes (paper costs in parentheses):

* ``BITWISE``  — ``idx' = (idx & mask) | base``  (2 instrs, ~8 cycles).
  Requires pow2-sized, size-aligned partitions (``core.partition``
  invariants I1/I2).  Wrap-around semantics: an out-of-partition index is
  remapped *into the offender's own partition*; neighbours are never touched.
* ``MODULO``   — ``idx' = base + ((idx - base) mod size)`` (paper: ~28 cycles
  with an inline reciprocal instead of the libcall).  Works for arbitrary
  partition sizes.  We provide both the plain ``lax.rem`` form and the
  paper-faithful *reciprocal* form (`fence_modulo_magic`) built from a
  precomputed magic multiplier — no hardware divide on the hot path.
* ``CHECK``    — compare + select (paper: ~80 cycles, 1.7x app slowdown).
  The only mode that *detects* OOB; returns an ``ok`` predicate alongside a
  safe index (clamped to ``base``), so the manager can report the fault and
  kill the offending tenant kernel (fault isolation with detection).

``NONE`` is the standalone fast-path (§4.2.3: "when the grdManager detects
that an application runs standalone, it issues a native kernel").
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition, is_pow2


class FencePolicy(enum.Enum):
    """Which bounds mode the manager applies (§4.4)."""

    NONE = "none"          # native kernel — standalone fast-path
    BITWISE = "bitwise"    # address fencing, bitwise AND/OR (headline mode)
    MODULO = "modulo"      # address fencing, inline modulo
    CHECK = "check"        # address checking (detects OOB; debug / strict)


# ---------------------------------------------------------------------------
# Magic-number (reciprocal) unsigned division, n < 2**31.
#
# The paper implements the 64-bit modulo "inline with three instructions and
# an extra parameter holding 1/partition_size" to avoid CUDA's div libcall.
# The TPU analogue: precompute (m, s) on the host such that
#     n // d == (n * m) >> s         for all 0 <= n < 2**31,
# and evaluate the 32x32->64 high-multiply with 16-bit limbs (no int64
# needed; JAX x64 stays disabled).
# ---------------------------------------------------------------------------

_MAGIC_DOMAIN_BITS = 31  # we mask indices into [0, 2**31) first (1 extra op)


def magic_constants(d: int) -> Tuple[int, int]:
    """Precompute (m, s) with ``n // d == (n * m) >> s`` for n < 2**31.

    Uses the classic round-up method: s = 31 + ceil(log2 d), m = ceil(2^s/d).
    For this domain m always fits in 32 unsigned bits (verified by the
    hypothesis sweep in tests/test_fence.py).
    """
    if d <= 0:
        raise ValueError(f"divisor must be positive, got {d}")
    if d == 1:
        return 1, 0
    log2d = (d - 1).bit_length()  # ceil(log2 d)
    s = _MAGIC_DOMAIN_BITS + log2d
    m = (1 << s) + d - 1
    m //= d
    assert m < (1 << 32), (d, m)
    return m, s


def _umul_hi32_and_shift(n: jax.Array, m: int, s: int) -> jax.Array:
    """Compute ``(n * m) >> s`` for 0 <= n < 2**31, 0 < m < 2**32, s >= 32,
    without 64-bit integers, via 16-bit limb decomposition in uint32.

    uint32 arithmetic wraps mod 2^32 and shifts logically, so the carry
    chain below is exact:

        prod = ll + (lh + hl + (ll>>16)) << 16 + hh << 32

    with each accumulation step kept < 2^32 (proof in comments).  Returns
    int32 (the quotient is < 2^31 because n < 2^31 and m/2^s <= 1/d <= 1).
    """
    n = n.astype(jnp.uint32)
    n_lo = n & jnp.uint32(0xFFFF)          # < 2^16
    n_hi = n >> jnp.uint32(16)             # < 2^15  (n < 2^31)
    m_lo = np.uint32(m & 0xFFFF)
    m_hi = np.uint32((m >> 16) & 0xFFFF)

    ll = n_lo * m_lo                       # < 2^32, exact
    lh = n_lo * m_hi                       # <= (2^16-1)^2 < 2^32 - 2^17
    hl = n_hi * m_lo                       # < 2^31
    hh = n_hi * m_hi                       # < 2^31

    mid1 = lh + (ll >> jnp.uint32(16))     # < (2^16-1)^2 + 2^16 < 2^32, exact
    # mid1 + hl may exceed 2^32 -> split into 16-bit halves with carry.
    mid_lo = (mid1 & jnp.uint32(0xFFFF)) + (hl & jnp.uint32(0xFFFF))  # < 2^17
    mid_hi = (mid1 >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid_lo >> jnp.uint32(16)
    )                                       # < 2^16 + 2^15 + 2 < 2^17
    hi = hh + mid_hi                        # < 2^31 + 2^17 < 2^32, exact hi word

    sh = s - 32
    assert 0 <= sh < 32, s
    q = hi >> jnp.uint32(sh) if sh else hi
    return q.astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class FenceParams:
    """The per-tenant scalar row passed to kernels (paper: "two extra kernel
    parameters" -> 2 registers; here: scalar operands -> SMEM).

    ``base``/``size`` may be Python ints (static — per-tenant specialized
    binary, which the paper rejects as unscalable) **or traced int32 scalars**
    (dynamic — one shared binary, bounds passed at launch time, the paper's
    actual design).  MODULO's magic constants require a concrete size
    (the shift amount is structural), so that mode compiles per-partition.
    """

    base: Any
    size: Any

    def __post_init__(self):
        if isinstance(self.size, int) and self.size <= 0:
            raise ValueError("partition size must be positive")

    @property
    def is_static(self) -> bool:
        return isinstance(self.base, int) and isinstance(self.size, int)

    @property
    def mask(self):
        """``size - 1`` — only a valid bitwise fence mask for pow2 sizes.

        Static sizes are checked here.  **Traced sizes cannot be checked at
        trace time**: ``size - 1`` is returned unconditionally, and the
        wrap guarantee of BITWISE silently breaks if the traced value is
        not a power of two.  The contract is therefore that every caller
        building traced params from host-known sizes validates them first
        with :func:`require_pow2_sizes` — the manager, the serve engine and
        :class:`FenceTable` all do (partitions from the buddy allocator are
        pow2 by construction; this guards hand-built params).
        """
        if isinstance(self.size, int):
            if not is_pow2(self.size):
                raise ValueError("mask only defined for pow2 partitions")
            return self.size - 1
        return self.size - 1  # traced: caller validated via require_pow2_sizes

    @property
    def magic(self) -> Tuple[int, int]:
        if not isinstance(self.size, int):
            raise ValueError(
                "MODULO fencing needs a concrete partition size (the shift "
                "amount is structural); use static FenceParams"
            )
        return magic_constants(self.size)

    @classmethod
    def from_partition(cls, part: Partition) -> "FenceParams":
        return cls(base=part.base, size=part.size)

    def contains(self, lo: int, hi: Optional[int] = None) -> bool:
        hi = lo + 1 if hi is None else hi
        return self.base <= lo and hi <= self.base + self.size


def require_pow2_sizes(sizes) -> None:
    """Host-side guard for building *traced* fence params (see
    :attr:`FenceParams.mask`): every size must be a positive power of two.

    Accepts a scalar or any array-like of host-known ints.  Raises
    ``ValueError`` listing the offending sizes; a traced (abstract) input is
    a programming error and also raises.
    """
    arr = np.asarray(sizes)
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"require_pow2_sizes needs host-known integer sizes, got "
            f"dtype {arr.dtype}: validate before staging to device")
    flat = arr.reshape(-1).astype(np.int64)
    bad = flat[(flat <= 0) | ((flat & (flat - 1)) != 0)]
    if bad.size:
        raise ValueError(
            f"partition sizes must be positive powers of two for bitwise "
            f"fencing (invariant I1); offenders: {sorted(set(bad.tolist()))}")


@dataclasses.dataclass(frozen=True)
class FenceTable:
    """Stacked per-tenant fence rows — the batched form of
    :class:`FenceParams` (one ``(base, mask)`` int32 row per tenant).

    This is what the batched multi-tenant scheduler passes to a fused
    device step: a single ``(T, 2)`` int32 table of dynamic scalars, so one
    compiled binary serves any set of tenants (the paper's "two extra
    kernel parameters", vectorized across tenants — no per-tenant
    recompiles).  Row ``r`` fences row ``r`` of the fused batch; a
    tenant-id *column* can gather per-element params for row-mixed batches
    (the serving engine's per-row guard).
    """

    rows: jax.Array            # (T, 2) int32: rows[r] = (base, mask)

    @classmethod
    def from_partitions(cls, parts: Sequence[Partition]) -> "FenceTable":
        if not parts:
            raise ValueError("FenceTable needs at least one partition")
        require_pow2_sizes([p.size for p in parts])
        arr = np.array([[p.base, p.mask] for p in parts], dtype=np.int32)
        return cls(rows=jnp.asarray(arr))

    @classmethod
    def from_bounds(cls, base, size) -> "FenceTable":
        """Build from host (base, size) arrays, validating pow2 sizes."""
        base = np.asarray(base, np.int32).reshape(-1)
        size = np.asarray(size, np.int64).reshape(-1)
        require_pow2_sizes(size)
        arr = np.stack([base, (size - 1).astype(np.int32)], axis=1)
        return cls(rows=jnp.asarray(arr.astype(np.int32)))

    def __len__(self) -> int:
        return int(self.rows.shape[0])

    def row_params(self, row) -> FenceParams:
        """Traced FenceParams for one table row (fused-step row ``r``)."""
        return FenceParams(base=self.rows[row, 0],
                           size=self.rows[row, 1] + 1)

    def gather(self, tenant_col: jax.Array) -> FenceParams:
        """Per-element FenceParams for a tenant-id column.

        ``tenant_col[i]`` selects the table row fencing element ``i``; the
        returned params hold ``(N,)`` base/size arrays that broadcast
        elementwise through the fences (batched serving, §4.2.4).
        """
        col = jnp.asarray(tenant_col, jnp.int32)
        base = jnp.take(self.rows[:, 0], col, axis=0)
        mask = jnp.take(self.rows[:, 1], col, axis=0)
        return FenceParams(base=base, size=mask + 1)


# ---------------------------------------------------------------------------
# The three fences.  All take/return integer index arrays (any shape, int32).
# ---------------------------------------------------------------------------


def fence_bitwise(idx: jax.Array, base, mask) -> jax.Array:
    """``(idx & mask) | base`` — Guardian's headline mode (Listing 1).

    With base size-aligned and mask = size-1 this maps any int32 into
    [base, base+size) and is the identity inside the partition.
    """
    idx = jnp.asarray(idx, jnp.int32)
    mask = jnp.asarray(mask, jnp.int32)
    base = jnp.asarray(base, jnp.int32)
    return jnp.bitwise_or(jnp.bitwise_and(idx, mask), base)


def fence_modulo(idx: jax.Array, base, size) -> jax.Array:
    """``base + ((idx - base) mod size)`` with floor-mod semantics.

    Plain form (lets XLA lower the remainder however it likes).  Arbitrary
    partition sizes.  Matches the paper's *semantics*; the cost-faithful
    reciprocal form is `fence_modulo_magic`.
    """
    idx = jnp.asarray(idx, jnp.int32)
    base = jnp.asarray(base, jnp.int32)
    size = jnp.asarray(size, jnp.int32)
    off = idx - base
    # Bring into the non-negative domain first: floor-mod of a negative int32
    # is already non-negative in jnp, but we mirror the magic variant so the
    # two are bit-identical (see tests).
    off = jnp.bitwise_and(off, jnp.int32(0x7FFFFFFF))
    return base + jnp.remainder(off, size)


def fence_modulo_magic(idx: jax.Array, base, size, m: int, s: int) -> jax.Array:
    """Reciprocal-multiply modulo — the paper's "inline 64-bit modulo with
    three instructions and an extra parameter holding 1/partition_size".

    idx' = base + (off - (off // size) * size),  off = (idx - base) & 0x7fffffff
    where the division is a precomputed magic multiply-high + shift.
    """
    idx = jnp.asarray(idx, jnp.int32)
    if size == 1:  # degenerate partition: every access maps to base
        return jnp.full(idx.shape, base, jnp.int32)
    off = jnp.bitwise_and(idx - jnp.int32(base), jnp.int32(0x7FFFFFFF))
    q = _umul_hi32_and_shift(off, m, s)
    rem = off - q * jnp.int32(size)
    return jnp.int32(base) + rem


def fence_check(idx: jax.Array, base, size) -> Tuple[jax.Array, jax.Array]:
    """Address checking: returns (safe_idx, ok).

    ``ok`` is False wherever idx was out of partition; safe_idx is clamped to
    ``base`` there so downstream accesses stay in-partition.  The manager
    reads ``ok`` to detect the fault (paper: "detect invalid accesses and
    return from the kernel").
    """
    idx = jnp.asarray(idx, jnp.int32)
    lo = jnp.asarray(base, jnp.int32)
    hi = lo + jnp.asarray(size, jnp.int32)
    ok = jnp.logical_and(idx >= lo, idx < hi)
    safe = jnp.where(ok, idx, lo)
    return safe, ok


def apply_fence(
    policy: FencePolicy,
    idx: jax.Array,
    params: FenceParams,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Dispatch on policy. Returns (fenced_idx, ok_or_None).

    ``ok`` is only produced by CHECK; fencing modes return None (they cannot
    detect, only contain — §4.4).
    """
    if policy is FencePolicy.NONE:
        return jnp.asarray(idx, jnp.int32), None
    if policy is FencePolicy.BITWISE:
        return fence_bitwise(idx, params.base, params.mask), None
    if policy is FencePolicy.MODULO:
        m, s = params.magic
        return fence_modulo_magic(idx, params.base, params.size, m, s), None
    if policy is FencePolicy.CHECK:
        return fence_check(idx, params.base, params.size)
    raise ValueError(f"unknown policy {policy}")


# ---------------------------------------------------------------------------
# Guarded arena ops — the XLA-level "sandboxed load/store".
#
# These are what the framework's own data paths use (paged-KV lookups,
# embedding gathers, expert dispatch) and what the jaxpr sandboxer inserts
# into tenant kernels.  Axis 0 of ``arena`` is the shared slot space.
# ---------------------------------------------------------------------------


def guarded_take(
    arena: jax.Array,
    idx: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced gather of arena rows: ``arena[fence(idx)]``."""
    fenced, _ = apply_fence(policy, idx, params)
    # The fence proves in-bounds-ness, so XLA's own OOB clamp is elided.
    return arena.at[fenced].get(mode="promise_in_bounds")


def guarded_update(
    arena: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced scatter of arena rows: ``arena.at[fence(idx)].set(values)``."""
    fenced, _ = apply_fence(policy, idx, params)
    return arena.at[fenced].set(values, mode="promise_in_bounds")


def guarded_add(
    arena: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    fenced, _ = apply_fence(policy, idx, params)
    return arena.at[fenced].add(values, mode="promise_in_bounds")


def guarded_dynamic_slice(
    arena: jax.Array,
    start: jax.Array,
    length: int,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced contiguous read of ``length`` rows starting at ``start``.

    Both endpoints are fenced; a read that would straddle the partition end
    is pinned so it stays inside (start clamped to base+size-length).
    """
    fenced, _ = apply_fence(policy, start, params)
    hi = jnp.maximum(jnp.asarray(params.base + params.size - length, jnp.int32),
                     jnp.asarray(params.base, jnp.int32))
    fenced = jnp.minimum(fenced, hi)
    return jax.lax.dynamic_slice_in_dim(arena, fenced, length, axis=0)


def guarded_dynamic_update_slice(
    arena: jax.Array,
    start: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    fenced, _ = apply_fence(policy, start, params)
    length = values.shape[0]
    hi = jnp.maximum(jnp.asarray(params.base + params.size - length, jnp.int32),
                     jnp.asarray(params.base, jnp.int32))
    fenced = jnp.minimum(fenced, hi)
    return jax.lax.dynamic_update_slice_in_dim(arena, values, fenced, axis=0)
