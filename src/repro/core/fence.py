"""Bounds fencing / checking primitives — Guardian §4.4 ("Bounds Checking
Tradeoffs"), adapted to TPU index spaces.

The paper instruments every PTX load/store with one of three bounds modes.
On TPU the analogous *dynamically computed address* is a data-dependent
integer index into a shared HBM arena (KV page ids, embedding rows, expert
offsets, state slots).  The fence is applied to the index *before* it is used
by a gather/scatter/DMA — the exact analogue of patching the PTX register
before ``ld.global``.

Three modes (paper costs in parentheses):

* ``BITWISE``  — ``idx' = (idx & mask) | base``  (2 instrs, ~8 cycles).
  Requires pow2-sized, size-aligned partitions (``core.partition``
  invariants I1/I2).  Wrap-around semantics: an out-of-partition index is
  remapped *into the offender's own partition*; neighbours are never touched.
* ``MODULO``   — ``idx' = base + ((idx - base) mod size)`` (paper: ~28 cycles
  with an inline reciprocal instead of the libcall).  Works for arbitrary
  partition sizes.  We provide both the plain ``lax.rem`` form and the
  paper-faithful *reciprocal* form (`fence_modulo_magic`) built from a
  precomputed magic multiplier — no hardware divide on the hot path.
* ``CHECK``    — compare + select (paper: ~80 cycles, 1.7x app slowdown).
  The only mode that *detects* OOB; returns an ``ok`` predicate alongside a
  safe index (clamped to ``base``), so the manager can report the fault and
  kill the offending tenant kernel (fault isolation with detection).

``NONE`` is the standalone fast-path (§4.2.3: "when the grdManager detects
that an application runs standalone, it issues a native kernel").
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition, is_pow2


class FencePolicy(enum.Enum):
    """Which bounds mode the manager applies (§4.4)."""

    NONE = "none"          # native kernel — standalone fast-path
    BITWISE = "bitwise"    # address fencing, bitwise AND/OR (headline mode)
    MODULO = "modulo"      # address fencing, inline modulo
    CHECK = "check"        # address checking (detects OOB; debug / strict)

    @property
    def code(self) -> int:
        """Stable int32 code for per-element policy columns in row-mixed
        batches (see :func:`apply_fence_mixed`)."""
        return _POLICY_CODE[self]


_POLICY_CODE = {
    FencePolicy.NONE: 0,
    FencePolicy.BITWISE: 1,
    FencePolicy.MODULO: 2,
    FencePolicy.CHECK: 3,
}


# ---------------------------------------------------------------------------
# Magic-number (reciprocal) unsigned division, n < 2**31.
#
# The paper implements the 64-bit modulo "inline with three instructions and
# an extra parameter holding 1/partition_size" to avoid CUDA's div libcall.
# The TPU analogue: precompute (m, s) on the host such that
#     n // d == (n * m) >> s         for all 0 <= n < 2**31,
# and evaluate the 32x32->64 high-multiply with 16-bit limbs (no int64
# needed; JAX x64 stays disabled).
# ---------------------------------------------------------------------------

_MAGIC_DOMAIN_BITS = 31  # we mask indices into [0, 2**31) first (1 extra op)


def magic_constants(d: int) -> Tuple[int, int]:
    """Precompute (m, s) with ``n // d == (n * m) >> s`` for n < 2**31.

    Uses the classic round-up method: s = 31 + ceil(log2 d), m = ceil(2^s/d).
    For this domain m always fits in 32 unsigned bits (verified by the
    hypothesis sweep in tests/test_fence.py).
    """
    if d <= 0:
        raise ValueError(f"divisor must be positive, got {d}")
    if d == 1:
        return 1, 0
    log2d = (d - 1).bit_length()  # ceil(log2 d)
    s = _MAGIC_DOMAIN_BITS + log2d
    m = (1 << s) + d - 1
    m //= d
    assert m < (1 << 32), (d, m)
    return m, s


def magic_row(d: int) -> Tuple[int, int]:
    """(m, s) for the *dynamic* magic row table.

    Identical to :func:`magic_constants` except the degenerate ``d == 1``
    divisor, whose shift (0) would underflow the traced ``s - 32``
    hi-word shift.  The dynamic fence masks the remainder to zero for
    size-1 rows (`fence_modulo_magic_dyn`), so the stored pair only needs
    a shift >= 32; (0, 32) yields q = 0 and the mask does the rest.
    """
    if d == 1:
        return 0, 32
    return magic_constants(d)


def _umul_hi32_and_shift(n: jax.Array, m: int, s: int) -> jax.Array:
    """Compute ``(n * m) >> s`` for 0 <= n < 2**31, 0 < m < 2**32, s >= 32,
    without 64-bit integers, via 16-bit limb decomposition in uint32.

    uint32 arithmetic wraps mod 2^32 and shifts logically, so the carry
    chain below is exact:

        prod = ll + (lh + hl + (ll>>16)) << 16 + hh << 32

    with each accumulation step kept < 2^32 (proof in comments).  Returns
    int32 (the quotient is < 2^31 because n < 2^31 and m/2^s <= 1/d <= 1).
    """
    n = n.astype(jnp.uint32)
    n_lo = n & jnp.uint32(0xFFFF)          # < 2^16
    n_hi = n >> jnp.uint32(16)             # < 2^15  (n < 2^31)
    m_lo = np.uint32(m & 0xFFFF)
    m_hi = np.uint32((m >> 16) & 0xFFFF)

    ll = n_lo * m_lo                       # < 2^32, exact
    lh = n_lo * m_hi                       # <= (2^16-1)^2 < 2^32 - 2^17
    hl = n_hi * m_lo                       # < 2^31
    hh = n_hi * m_hi                       # < 2^31

    mid1 = lh + (ll >> jnp.uint32(16))     # < (2^16-1)^2 + 2^16 < 2^32, exact
    # mid1 + hl may exceed 2^32 -> split into 16-bit halves with carry.
    mid_lo = (mid1 & jnp.uint32(0xFFFF)) + (hl & jnp.uint32(0xFFFF))  # < 2^17
    mid_hi = (mid1 >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid_lo >> jnp.uint32(16)
    )                                       # < 2^16 + 2^15 + 2 < 2^17
    hi = hh + mid_hi                        # < 2^31 + 2^17 < 2^32, exact hi word

    sh = s - 32
    assert 0 <= sh < 32, s
    q = hi >> jnp.uint32(sh) if sh else hi
    return q.astype(jnp.int32)


def _umul_hi32_and_shift_dyn(n: jax.Array, m, s) -> jax.Array:
    """Traced-magic twin of :func:`_umul_hi32_and_shift`.

    ``m``/``s`` arrive as *dynamic* operands — int32 scalars or arrays from
    a magic row table (``m`` is the uint32 multiplier's bit pattern stored
    in int32) — instead of Python constants, so one compiled binary serves
    any tenant set.  Same 16-bit-limb carry chain, same exactness proof
    (n < 2^31, m < 2^32).  ``s`` must be >= 32 (guaranteed by
    :func:`magic_row` for every divisor).
    """
    n = jnp.asarray(n).astype(jnp.uint32)
    m = jax.lax.bitcast_convert_type(jnp.asarray(m, jnp.int32), jnp.uint32)
    n_lo = n & jnp.uint32(0xFFFF)
    n_hi = n >> jnp.uint32(16)
    m_lo = m & jnp.uint32(0xFFFF)
    m_hi = m >> jnp.uint32(16)

    ll = n_lo * m_lo
    lh = n_lo * m_hi
    hl = n_hi * m_lo
    hh = n_hi * m_hi

    mid1 = lh + (ll >> jnp.uint32(16))
    mid_lo = (mid1 & jnp.uint32(0xFFFF)) + (hl & jnp.uint32(0xFFFF))
    mid_hi = (mid1 >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid_lo >> jnp.uint32(16))
    hi = hh + mid_hi

    sh = (jnp.asarray(s, jnp.int32) - 32).astype(jnp.uint32)
    q = hi >> sh
    return q.astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class FenceParams:
    """The per-tenant scalar row passed to kernels (paper: "two extra kernel
    parameters" -> 2 registers; here: scalar operands -> SMEM).

    ``base``/``size`` may be Python ints (static — per-tenant specialized
    binary, which the paper rejects as unscalable) **or traced int32 scalars**
    (dynamic — one shared binary, bounds passed at launch time, the paper's
    actual design).  MODULO historically required a concrete size (the
    shift amount was structural, one binary per partition); with
    ``magic_m``/``magic_s`` populated — int32 scalars or arrays carrying a
    precomputed reciprocal row from a :class:`FenceTable` magic table —
    MODULO too becomes a dynamic-operand mode and fuses like BITWISE.
    """

    base: Any
    size: Any
    magic_m: Any = None    # uint32 multiplier bit-pattern (int32-stored)
    magic_s: Any = None    # shift amount, >= 32 (see magic_row)

    def __post_init__(self):
        if isinstance(self.size, int) and self.size <= 0:
            raise ValueError("partition size must be positive")

    @property
    def is_static(self) -> bool:
        return isinstance(self.base, int) and isinstance(self.size, int)

    @property
    def mask(self):
        """``size - 1`` — only a valid bitwise fence mask for pow2 sizes.

        Static sizes are checked here.  **Traced sizes cannot be checked at
        trace time**: ``size - 1`` is returned unconditionally, and the
        wrap guarantee of BITWISE silently breaks if the traced value is
        not a power of two.  The contract is therefore that every caller
        building traced params from host-known sizes validates them first
        with :func:`require_pow2_sizes` — the manager, the serve engine and
        :class:`FenceTable` all do (partitions from the buddy allocator are
        pow2 by construction; this guards hand-built params).
        """
        if isinstance(self.size, int):
            if not is_pow2(self.size):
                raise ValueError("mask only defined for pow2 partitions")
            return self.size - 1
        return self.size - 1  # traced: caller validated via require_pow2_sizes

    @property
    def magic(self) -> Tuple[int, int]:
        if not isinstance(self.size, int):
            raise ValueError(
                "MODULO fencing needs a concrete partition size (the shift "
                "amount is structural); use static FenceParams"
            )
        return magic_constants(self.size)

    @classmethod
    def from_partition(cls, part: Partition) -> "FenceParams":
        return cls(base=part.base, size=part.size)

    def contains(self, lo: int, hi: Optional[int] = None) -> bool:
        hi = lo + 1 if hi is None else hi
        return self.base <= lo and hi <= self.base + self.size


_FP_FIELDS = ("base", "size", "magic_m", "magic_s")


def _fence_params_flatten(fp: "FenceParams"):
    """Pytree flattening with a *per-instance* static/dynamic split.

    Array-valued fields (traced bounds, magic-row columns) are children so
    FenceParams can ride through ``jax.jit`` as an operand (the jitted
    trusted-step path passes GuardSpecs this way); host-int fields stay
    aux data so a static-bounds guard keeps its concrete values — the
    MODULO static path needs a concrete size for its shift amount, and
    baking static bounds into the compiled step matches the eager path
    bit-for-bit.
    """
    vals = tuple(getattr(fp, n) for n in _FP_FIELDS)
    is_dyn = tuple(isinstance(v, (jax.Array, np.ndarray)) for v in vals)
    children = tuple(v for v, d in zip(vals, is_dyn) if d)
    static = tuple(None if d else v for v, d in zip(vals, is_dyn))
    return children, (is_dyn, static)


def _fence_params_unflatten(aux, children) -> "FenceParams":
    is_dyn, static = aux
    it = iter(children)
    return FenceParams(*(next(it) if d else s
                         for d, s in zip(is_dyn, static)))


jax.tree_util.register_pytree_node(
    FenceParams, _fence_params_flatten, _fence_params_unflatten)


def require_pow2_sizes(sizes) -> None:
    """Host-side guard for building *traced* fence params (see
    :attr:`FenceParams.mask`): every size must be a positive power of two.

    Accepts a scalar or any array-like of host-known ints.  Raises
    ``ValueError`` listing the offending sizes; a traced (abstract) input is
    a programming error and also raises.
    """
    arr = np.asarray(sizes)
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"require_pow2_sizes needs host-known integer sizes, got "
            f"dtype {arr.dtype}: validate before staging to device")
    flat = arr.reshape(-1).astype(np.int64)
    bad = flat[(flat <= 0) | ((flat & (flat - 1)) != 0)]
    if bad.size:
        raise ValueError(
            f"partition sizes must be positive powers of two for bitwise "
            f"fencing (invariant I1); offenders: {sorted(set(bad.tolist()))}")


@dataclasses.dataclass(frozen=True)
class FenceTable:
    """Stacked per-tenant fence rows — the batched form of
    :class:`FenceParams` (one ``(base, mask)`` int32 row per tenant).

    This is what the batched multi-tenant scheduler passes to a fused
    device step: a single ``(T, 2)`` int32 table of dynamic scalars, so one
    compiled binary serves any set of tenants (the paper's "two extra
    kernel parameters", vectorized across tenants — no per-tenant
    recompiles).  Row ``r`` fences row ``r`` of the fused batch; a
    tenant-id *column* can gather per-element params for row-mixed batches
    (the serving engine's per-row guard).

    ``magic`` is the optional per-row magic-constant table that lets
    MODULO batches fuse too: ``(T, 4)`` int32 of ``(base, size, m, s)``
    built from :func:`magic_row`.  Unlike the bitwise ``rows`` it supports
    arbitrary (non-pow2) partition sizes — a magic-only table
    (:meth:`modulo_from_bounds`) has ``rows is None``.
    """

    rows: Optional[jax.Array] = None   # (T, 2) int32: rows[r] = (base, mask)
    magic: Optional[jax.Array] = None  # (T, 4) int32: (base, size, m, s)

    def __post_init__(self):
        if self.rows is None and self.magic is None:
            raise ValueError("FenceTable needs bitwise rows, a magic "
                             "table, or both")

    @staticmethod
    def _magic_arr(bounds: Sequence[Tuple[int, int]]) -> jax.Array:
        arr = np.zeros((len(bounds), 4), np.uint32)
        for r, (base, size) in enumerate(bounds):
            m, s = magic_row(size)
            arr[r] = (base, size, m, s)
        return jnp.asarray(arr.view(np.int32))

    @classmethod
    def from_partitions(cls, parts: Sequence[Partition],
                        with_magic: bool = False) -> "FenceTable":
        if not parts:
            raise ValueError("FenceTable needs at least one partition")
        require_pow2_sizes([p.size for p in parts])
        arr = np.array([[p.base, p.mask] for p in parts], dtype=np.int32)
        magic = cls._magic_arr([(p.base, p.size) for p in parts]) \
            if with_magic else None
        return cls(rows=jnp.asarray(arr), magic=magic)

    @classmethod
    def from_bounds(cls, base, size) -> "FenceTable":
        """Build from host (base, size) arrays, validating pow2 sizes."""
        base = np.asarray(base, np.int32).reshape(-1)
        size = np.asarray(size, np.int64).reshape(-1)
        require_pow2_sizes(size)
        arr = np.stack([base, (size - 1).astype(np.int32)], axis=1)
        return cls(rows=jnp.asarray(arr.astype(np.int32)))

    @classmethod
    def modulo_from_bounds(cls, base, size) -> "FenceTable":
        """Magic-only table for arbitrary (incl. non-pow2) partition sizes.

        No bitwise rows are built — a non-pow2 ``size - 1`` is not a valid
        wrap mask — so the table fences through MODULO/CHECK only.
        """
        base = np.asarray(base, np.int64).reshape(-1)
        size = np.asarray(size, np.int64).reshape(-1)
        if (size <= 0).any():
            raise ValueError("partition sizes must be positive")
        return cls(magic=cls._magic_arr(list(zip(base.tolist(),
                                                 size.tolist()))))

    def __len__(self) -> int:
        arr = self.rows if self.rows is not None else self.magic
        return int(arr.shape[0])

    def row_params(self, row) -> FenceParams:
        """Traced FenceParams for one table row (fused-step row ``r``)."""
        if self.rows is None:
            return self.magic_row_params(row)
        return FenceParams(base=self.rows[row, 0],
                           size=self.rows[row, 1] + 1)

    def magic_row_params(self, row) -> FenceParams:
        """Traced magic-carrying FenceParams for one magic-table row."""
        if self.magic is None:
            raise ValueError("table was built without a magic row table")
        return FenceParams(base=self.magic[row, 0], size=self.magic[row, 1],
                           magic_m=self.magic[row, 2],
                           magic_s=self.magic[row, 3])

    def gather(self, tenant_col: jax.Array) -> FenceParams:
        """Per-element FenceParams for a tenant-id column.

        ``tenant_col[i]`` selects the table row fencing element ``i``; the
        returned params hold ``(N,)`` base/size arrays that broadcast
        elementwise through the fences (batched serving, §4.2.4).  When the
        table carries magic rows the params also carry per-element magic
        columns, so MODULO (and row-mixed) policies fence dynamically.
        """
        col = jnp.asarray(tenant_col, jnp.int32)
        if self.rows is not None:
            base = jnp.take(self.rows[:, 0], col, axis=0)
            mask = jnp.take(self.rows[:, 1], col, axis=0)
            base, size = base, mask + 1
        else:
            base = jnp.take(self.magic[:, 0], col, axis=0)
            size = jnp.take(self.magic[:, 1], col, axis=0)
        if self.magic is None:
            return FenceParams(base=base, size=size)
        return FenceParams(
            base=base, size=size,
            magic_m=jnp.take(self.magic[:, 2], col, axis=0),
            magic_s=jnp.take(self.magic[:, 3], col, axis=0))


# ---------------------------------------------------------------------------
# The three fences.  All take/return integer index arrays (any shape, int32).
# ---------------------------------------------------------------------------


def fence_bitwise(idx: jax.Array, base, mask) -> jax.Array:
    """``(idx & mask) | base`` — Guardian's headline mode (Listing 1).

    With base size-aligned and mask = size-1 this maps any int32 into
    [base, base+size) and is the identity inside the partition.
    """
    idx = jnp.asarray(idx, jnp.int32)
    mask = jnp.asarray(mask, jnp.int32)
    base = jnp.asarray(base, jnp.int32)
    return jnp.bitwise_or(jnp.bitwise_and(idx, mask), base)


def fence_modulo(idx: jax.Array, base, size) -> jax.Array:
    """``base + ((idx - base) mod size)`` with floor-mod semantics.

    Plain form (lets XLA lower the remainder however it likes).  Arbitrary
    partition sizes.  Matches the paper's *semantics*; the cost-faithful
    reciprocal form is `fence_modulo_magic`.
    """
    idx = jnp.asarray(idx, jnp.int32)
    base = jnp.asarray(base, jnp.int32)
    size = jnp.asarray(size, jnp.int32)
    off = idx - base
    # Bring into the non-negative domain first: floor-mod of a negative int32
    # is already non-negative in jnp, but we mirror the magic variant so the
    # two are bit-identical (see tests).
    off = jnp.bitwise_and(off, jnp.int32(0x7FFFFFFF))
    return base + jnp.remainder(off, size)


def fence_modulo_magic(idx: jax.Array, base, size, m: int, s: int) -> jax.Array:
    """Reciprocal-multiply modulo — the paper's "inline 64-bit modulo with
    three instructions and an extra parameter holding 1/partition_size".

    idx' = base + (off - (off // size) * size),  off = (idx - base) & 0x7fffffff
    where the division is a precomputed magic multiply-high + shift.
    """
    idx = jnp.asarray(idx, jnp.int32)
    if size == 1:  # degenerate partition: every access maps to base
        return jnp.full(idx.shape, base, jnp.int32)
    off = jnp.bitwise_and(idx - jnp.int32(base), jnp.int32(0x7FFFFFFF))
    q = _umul_hi32_and_shift(off, m, s)
    rem = off - q * jnp.int32(size)
    return jnp.int32(base) + rem


def fence_modulo_magic_dyn(idx: jax.Array, base, size, m, s) -> jax.Array:
    """Reciprocal modulo with *traced* magic constants — the fused-batch
    form of :func:`fence_modulo_magic`.

    ``(base, size, m, s)`` are dynamic operands (one magic row of a
    :class:`FenceTable`), so a single compiled binary fences any tenant
    set — the missing piece that historically kept MODULO launches out of
    fused device steps.  Bit-identical to the static form for every
    divisor (the division is exact either way); size-1 rows are handled by
    masking the remainder to zero (see :func:`magic_row`).
    """
    idx = jnp.asarray(idx, jnp.int32)
    base = jnp.asarray(base, jnp.int32)
    size = jnp.asarray(size, jnp.int32)
    off = jnp.bitwise_and(idx - base, jnp.int32(0x7FFFFFFF))
    q = _umul_hi32_and_shift_dyn(off, m, s)
    rem = off - q * size
    rem = jnp.where(size == 1, jnp.int32(0), rem)
    return base + rem


def apply_fence_mixed(
    codes: jax.Array,
    idx: jax.Array,
    params: FenceParams,
) -> Tuple[jax.Array, jax.Array]:
    """Per-element policy dispatch for row-mixed batches (serving plane).

    ``codes[i]`` is the :attr:`FencePolicy.code` fencing element ``i`` —
    gathered through the same tenant-id column as ``params`` — so one
    batched step can mix BITWISE, MODULO and CHECK tenants.  Requires
    ``params`` built from a magic-carrying table (``magic_m`` set) because
    the MODULO branch is compiled in unconditionally; BITWISE masks are
    only correct because partitions are pow2 by construction (buddy
    invariant I1, validated when the table was staged).

    Returns ``(fenced, ok)`` where ``ok`` is True everywhere except
    CHECK-policy elements that were out of bounds (the serving engine
    folds it into the ViolationLog).
    """
    if params.magic_m is None:
        raise ValueError(
            "apply_fence_mixed needs magic-carrying FenceParams (build the "
            "FenceTable with with_magic=True)")
    idx = jnp.asarray(idx, jnp.int32)
    codes = jnp.asarray(codes, jnp.int32)
    bitwise = fence_bitwise(idx, params.base, params.size - 1)
    modulo = fence_modulo_magic_dyn(idx, params.base, params.size,
                                    params.magic_m, params.magic_s)
    checked, ok_chk = fence_check(idx, params.base, params.size)
    fenced = jnp.select(
        [codes == FencePolicy.BITWISE.code,
         codes == FencePolicy.MODULO.code,
         codes == FencePolicy.CHECK.code],
        [bitwise, modulo, checked],
        idx)                                  # NONE: native passthrough
    ok = jnp.where(codes == FencePolicy.CHECK.code, ok_chk, True)
    return fenced, ok


def fence_check(idx: jax.Array, base, size) -> Tuple[jax.Array, jax.Array]:
    """Address checking: returns (safe_idx, ok).

    ``ok`` is False wherever idx was out of partition; safe_idx is clamped to
    ``base`` there so downstream accesses stay in-partition.  The manager
    reads ``ok`` to detect the fault (paper: "detect invalid accesses and
    return from the kernel").
    """
    idx = jnp.asarray(idx, jnp.int32)
    lo = jnp.asarray(base, jnp.int32)
    hi = lo + jnp.asarray(size, jnp.int32)
    ok = jnp.logical_and(idx >= lo, idx < hi)
    safe = jnp.where(ok, idx, lo)
    return safe, ok


def apply_fence(
    policy: FencePolicy,
    idx: jax.Array,
    params: FenceParams,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Dispatch on policy. Returns (fenced_idx, ok_or_None).

    ``ok`` is only produced by CHECK; fencing modes return None (they cannot
    detect, only contain — §4.4).
    """
    if policy is FencePolicy.NONE:
        return jnp.asarray(idx, jnp.int32), None
    if policy is FencePolicy.BITWISE:
        return fence_bitwise(idx, params.base, params.mask), None
    if policy is FencePolicy.MODULO:
        if params.magic_m is not None:
            return fence_modulo_magic_dyn(
                idx, params.base, params.size,
                params.magic_m, params.magic_s), None
        m, s = params.magic
        return fence_modulo_magic(idx, params.base, params.size, m, s), None
    if policy is FencePolicy.CHECK:
        return fence_check(idx, params.base, params.size)
    raise ValueError(f"unknown policy {policy}")


# ---------------------------------------------------------------------------
# Guarded arena ops — the XLA-level "sandboxed load/store".
#
# These are what the framework's own data paths use (paged-KV lookups,
# embedding gathers, expert dispatch) and what the jaxpr sandboxer inserts
# into tenant kernels.  Axis 0 of ``arena`` is the shared slot space.
# ---------------------------------------------------------------------------


def guarded_take(
    arena: jax.Array,
    idx: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced gather of arena rows: ``arena[fence(idx)]``."""
    fenced, _ = apply_fence(policy, idx, params)
    # The fence proves in-bounds-ness, so XLA's own OOB clamp is elided.
    return arena.at[fenced].get(mode="promise_in_bounds")


def guarded_update(
    arena: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced scatter of arena rows: ``arena.at[fence(idx)].set(values)``."""
    fenced, _ = apply_fence(policy, idx, params)
    return arena.at[fenced].set(values, mode="promise_in_bounds")


def guarded_add(
    arena: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    fenced, _ = apply_fence(policy, idx, params)
    return arena.at[fenced].add(values, mode="promise_in_bounds")


def guarded_dynamic_slice(
    arena: jax.Array,
    start: jax.Array,
    length: int,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    """Fenced contiguous read of ``length`` rows starting at ``start``.

    Both endpoints are fenced; a read that would straddle the partition end
    is pinned so it stays inside (start clamped to base+size-length).
    """
    fenced, _ = apply_fence(policy, start, params)
    hi = jnp.maximum(jnp.asarray(params.base + params.size - length, jnp.int32),
                     jnp.asarray(params.base, jnp.int32))
    fenced = jnp.minimum(fenced, hi)
    return jax.lax.dynamic_slice_in_dim(arena, fenced, length, axis=0)


def guarded_dynamic_update_slice(
    arena: jax.Array,
    start: jax.Array,
    values: jax.Array,
    params: FenceParams,
    policy: FencePolicy = FencePolicy.BITWISE,
) -> jax.Array:
    fenced, _ = apply_fence(policy, start, params)
    length = values.shape[0]
    hi = jnp.maximum(jnp.asarray(params.base + params.size - length, jnp.int32),
                     jnp.asarray(params.base, jnp.int32))
    fenced = jnp.minimum(fenced, hi)
    return jax.lax.dynamic_update_slice_in_dim(arena, values, fenced, axis=0)
