"""Device-side per-tenant violation telemetry — fault *attribution* for
Guardian's CHECK mode (§4.4, "detect invalid accesses and return from the
kernel"), grown into an accounting substrate.

The CHECK fence is the only bounds mode that *detects* out-of-bounds
accesses (BITWISE/MODULO contain silently).  Detection alone is not
containment policy: to quarantine a misbehaving tenant the manager needs to
know *who* violated, *how often*, and *through which access class* — without
synchronizing the device on every launch.

:class:`ViolationLog` is that substrate: a ``(T, K)`` int32 array living in
device memory beside the scheduler's
:class:`~repro.core.fence.FenceTable`, one row per tenant and one column
per access class (:class:`ViolationKind`: gather / scatter / dynamic-slice /
dynamic-update).  Fused CHECK steps fold their per-row violation counts into
the log *inside the compiled step* (a pure ``log.at[row].add(counts)`` —
no host round-trip on the hot path); the host only syncs when a
:class:`~repro.core.quarantine.QuarantineManager` polls the log or the
operator asks for :meth:`GuardianManager.violation_report`.

Rows are assigned on tenant registration and recycled on removal, so the
log's capacity bounds the number of *co-resident* tenants, not the number of
tenants over the manager's lifetime.
"""

from __future__ import annotations

import enum
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ViolationKind(enum.IntEnum):
    """Access classes the sandbox fences — the log's column space.

    Mirrors the four rewrite sites of the jaxpr sandboxer
    (:mod:`repro.core.sandbox`): gather / scatter index columns and
    dynamic-slice / dynamic-update start offsets.
    """

    GATHER = 0
    SCATTER = 1
    SLICE = 2
    UPDATE = 3


NUM_KINDS = len(ViolationKind)

#: Column order of a log row, for reports and CSV headers.
KIND_NAMES = tuple(k.name.lower() for k in ViolationKind)


class ViolationLog:
    """Per-tenant, per-kind OOB counters in device memory.

    The buffer is functionally updated like the arenas: traced code returns
    a new ``(T, K)`` array and the manager commits it.  Host reads
    (:meth:`snapshot`, :meth:`counts`) synchronize; the ``dirty`` flag lets
    the QuarantineManager skip the sync entirely when no CHECK launch has
    run since its last poll (BITWISE/MODULO traffic never touches the
    log).  Only the poller clears the flag — operator reads
    (``violation_report`` etc.) must not suppress containment.
    """

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("ViolationLog capacity must be >= 1")
        self.capacity = capacity
        self.buf: jax.Array = jnp.zeros((capacity, NUM_KINDS), jnp.int32)
        self._row_of: Dict[str, int] = {}
        self._free_rows: List[int] = list(range(capacity - 1, -1, -1))
        #: True iff a CHECK launch may have written since the poller's last
        #: look.  Set by the launch paths, cleared ONLY by the quarantine
        #: poll (QuarantineManager.poll) — never by plain reads.
        self.dirty = False

    # ------------------------------------------------------------------ #
    # Row lifecycle                                                      #
    # ------------------------------------------------------------------ #
    def assign(self, tenant_id: str) -> int:
        """Give ``tenant_id`` a log row (idempotent), growing the table
        when full — the ``(T, K)`` buffer is elastic like the fence
        tables, so ``capacity`` is a starting size, not a tenant cap."""
        row = self._row_of.get(tenant_id)
        if row is not None:
            return row
        if not self._free_rows:
            self._grow(self.capacity * 2)
        row = self._free_rows.pop()
        self._row_of[tenant_id] = row
        return row

    def _grow(self, new_capacity: int) -> None:
        """Double the device table.  Existing rows keep their indices
        (staged row-id vectors and in-flight attributions stay valid);
        the new rows join the free list *below* the old pop position, so
        assignment order continues ascending through the fresh block.
        Cached fused CHECK binaries retrace automatically on the new
        ``(T', K)`` operand shape — a one-time compile, never staleness.
        """
        if new_capacity <= self.capacity:
            return
        pad = jnp.zeros((new_capacity - self.capacity, NUM_KINDS),
                        jnp.int32)
        self.buf = jnp.concatenate([self.buf, pad], axis=0)
        self._free_rows = (
            list(range(new_capacity - 1, self.capacity - 1, -1))
            + self._free_rows)
        self.capacity = new_capacity

    def release(self, tenant_id: str) -> None:
        """Recycle a tenant's row, zeroing it for the next occupant."""
        row = self._row_of.pop(tenant_id, None)
        if row is None:
            return
        self.buf = self.buf.at[row].set(0)
        self._free_rows.append(row)

    def row_of(self, tenant_id: str) -> Optional[int]:
        return self._row_of.get(tenant_id)

    def tenants(self) -> List[str]:
        return list(self._row_of)

    # ------------------------------------------------------------------ #
    # Device-side accumulation                                           #
    # ------------------------------------------------------------------ #
    def add(self, tenant_id: str, counts: jax.Array) -> None:
        """Fold a ``(K,)`` count vector into the tenant's row.

        ``counts`` may be traced (the output of a CHECK launch) — the update
        stays on device; nothing synchronizes here.
        """
        row = self._row_of[tenant_id]
        self.buf = self.buf.at[row].add(jnp.asarray(counts, jnp.int32))
        self.dirty = True

    def reset(self, tenant_id: str) -> None:
        """Zero one tenant's counters (re-admission wipes the slate)."""
        row = self._row_of.get(tenant_id)
        if row is not None:
            self.buf = self.buf.at[row].set(0)

    # ------------------------------------------------------------------ #
    # Host-side reads (synchronizing)                                    #
    # ------------------------------------------------------------------ #
    def snapshot(self) -> np.ndarray:
        """Host copy of the full ``(T, K)`` table (``dirty`` untouched)."""
        return np.asarray(self.buf)

    def counts(self, tenant_id: str,
               snap: Optional[np.ndarray] = None) -> Dict[str, int]:
        """{kind name: count} for one tenant (pass ``snap`` to amortize)."""
        row = self._row_of[tenant_id]
        snap = self.snapshot() if snap is None else snap
        return {name: int(snap[row, k])
                for k, name in enumerate(KIND_NAMES)}

    def total(self, tenant_id: str,
              snap: Optional[np.ndarray] = None) -> int:
        row = self._row_of[tenant_id]
        snap = self.snapshot() if snap is None else snap
        return int(snap[row].sum())
