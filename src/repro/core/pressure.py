"""Allocation-pressure telemetry — the host-side substrate the elastic
partition subsystem (:mod:`repro.core.elastic`) reasons over.

Guardian sizes a tenant's partition once at registration; making the
partitions *elastic* needs a signal that says when a partition is too
small (allocations bumping against the top) or too large (mostly idle
reservation).  That signal must never touch the launch hot path: like the
:class:`~repro.core.violations.ViolationLog`, pressure is **sampled at
drain-cycle boundaries** behind a dirty flag — a cycle in which no
tenant's allocator moved costs one boolean read.

Everything here is host arithmetic over allocator metadata the manager
already owns (``IntraPartitionAllocator.live_bytes``, partition sizes, the
serve engine's occupied-slot counts): no device sync, ever.  The same
:class:`Ewma` smoother feeds the scheduler's adaptive-lookahead budget
(arrival rates over drain cycles — see
:class:`~repro.core.scheduler.BatchedLaunchScheduler`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple


class Ewma:
    """Exponentially-weighted moving average with a first-sample seed.

    ``alpha`` weights the newest sample; the first update seeds the value
    exactly (no bias toward an arbitrary zero start).  Deterministic —
    the adaptive-lookahead tests mirror it with plain arithmetic.
    """

    def __init__(self, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.value = 0.0
        self.samples = 0

    def update(self, x: float) -> float:
        if self.samples == 0:
            self.value = float(x)
        else:
            self.value = self.alpha * float(x) + (1 - self.alpha) * self.value
        self.samples += 1
        return self.value


@dataclasses.dataclass
class PressureSample:
    """One tenant's allocation pressure at a drain-cycle boundary.

    ``utilization`` is live slots / partition size (instantaneous);
    ``ewma`` the smoothed series the watermarks compare against.
    ``shrinkable`` records whether the sampler knows how to *move* the
    tenant's live data (suballoc-tracked raw tenants repack; serve
    engines report occupancy but own their slot placement, so the
    elastic manager may grow or relocate them wholesale but never
    shrink them in place).
    """

    tenant_id: str
    live: int
    size: int
    ewma: float
    shrinkable: bool = True
    #: intra-partition allocation failures since the last sample — the
    #: hard grow signal (a tenant hitting its ceiling is past any
    #: watermark debate)
    failures: int = 0

    @property
    def utilization(self) -> float:
        return self.live / self.size if self.size else 1.0


class PressureTracker:
    """Per-tenant allocation-pressure accounting, dirty-flag gated.

    The manager calls :meth:`note_alloc` / :meth:`note_free` /
    :meth:`note_failure` from its (host-side) allocator paths — each is a
    dict write plus a flag set.  The elastic manager calls
    :meth:`sample` at drain-cycle boundaries; a clean tracker returns
    ``[]`` without touching any per-tenant state.  Serve engines report
    slot occupancy through :meth:`observe` (they have no suballocator),
    which marks the tenant non-shrinkable — see
    :class:`PressureSample`.
    """

    def __init__(self, alpha: float = 0.5):
        self.alpha = alpha
        self.dirty = False
        self._ewma: Dict[str, Ewma] = {}
        self._dirty_tenants: set = set()
        self._observed: Dict[str, Tuple[int, int]] = {}  # tenant -> (live, size)
        self._failures: Dict[str, int] = {}

    # -- hot-path notes (host dict writes only) ------------------------- #
    def note_alloc(self, tenant_id: str) -> None:
        self._dirty_tenants.add(tenant_id)
        self.dirty = True

    note_free = note_alloc

    def note_failure(self, tenant_id: str) -> None:
        """An intra-partition allocation failed — the partition is hard
        full regardless of what the watermarks say."""
        self._failures[tenant_id] = self._failures.get(tenant_id, 0) + 1
        self._dirty_tenants.add(tenant_id)
        self.dirty = True

    def observe(self, tenant_id: str, live: int, size: int) -> None:
        """Serve-plane occupancy report (used slots / partition slots).
        Marks the tenant dirty and non-shrinkable."""
        self._observed[tenant_id] = (int(live), int(size))
        self._dirty_tenants.add(tenant_id)
        self.dirty = True

    def clear_failures(self, tenant_id: str) -> None:
        """The failure was already acted on (the malloc path grew the
        partition inline) — it must not drive a second grow at the next
        poll."""
        self._failures.pop(tenant_id, None)

    def forget(self, tenant_id: str) -> None:
        self._ewma.pop(tenant_id, None)
        self._observed.pop(tenant_id, None)
        self._failures.pop(tenant_id, None)
        self._dirty_tenants.discard(tenant_id)

    # -- cycle-boundary sampling ---------------------------------------- #
    def sample(self, live_of) -> List[PressureSample]:
        """Samples for every tenant dirtied since the last call.

        ``live_of(tenant_id) -> Optional[(live, size)]`` resolves a raw
        tenant's suballocator state; serve-observed tenants use their
        reported occupancy instead.  Consumes the dirty set.
        """
        if not self.dirty:
            return []
        out: List[PressureSample] = []
        for t in sorted(self._dirty_tenants):
            if t in self._observed:
                live, size = self._observed[t]
                shrinkable = False
            else:
                resolved = live_of(t)
                if resolved is None:
                    continue
                live, size = resolved
                shrinkable = True
            ew = self._ewma.get(t)
            if ew is None:
                ew = self._ewma[t] = Ewma(self.alpha)
            util = live / size if size else 1.0
            out.append(PressureSample(
                tenant_id=t, live=live, size=size,
                ewma=ew.update(util), shrinkable=shrinkable,
                failures=self._failures.pop(t, 0)))
        self._dirty_tenants.clear()
        self.dirty = False
        return out

    def ewma_of(self, tenant_id: str) -> Optional[float]:
        ew = self._ewma.get(tenant_id)
        return ew.value if ew is not None and ew.samples else None


def total_arrival_rate(ewmas: Iterable[Ewma]) -> float:
    """Sum of seeded per-tenant arrival-rate EWMAs (ops per drain cycle)
    — the *compute pressure* signal.  Feeds two consumers: the adaptive
    lookahead derivation below, and compute-aware admission
    (``ElasticPolicy.compute_watermark``): a best-effort admission
    waitlists while this total says the scheduler is already saturated
    enough to threaten a latency-critical tenant's budget.  Unseeded
    trackers contribute nothing (a cold scheduler exerts no pressure).
    """
    return sum(ew.value for ew in ewmas if ew.samples)


def derive_lookahead(rates: Iterable[float], max_fuse: int,
                     cap: int) -> int:
    """Adaptive cross-cycle lookahead budget from observed arrival rates.

    ``rates`` are per-tenant EWMA arrivals per drain cycle.  The budget
    is the expected number of cycles an under-filled batch must wait for
    compatible arrivals to fill it — ``ceil((max_fuse - 1) / total)`` —
    clamped to ``[0, cap]``:

    * dense traffic (``total >= max_fuse - 1``) fills batches within one
      cycle, so holding costs latency for nothing → budget 1;
    * sparse traffic would wait unboundedly → ``cap`` bounds the tail;
    * no observed traffic (cold scheduler) → 0, the flush-every-cycle
      default, so adaptive mode changes nothing until it has data.

    Pure host arithmetic, mirrored by the deterministic sweep in
    ``tests/test_scheduler.py``.
    """
    total = sum(rates)
    if total <= 0.0 or max_fuse <= 1:
        return 0
    need = (max_fuse - 1) / total
    budget = int(need) if need == int(need) else int(need) + 1
    return max(0, min(budget, cap))
