"""GuardianClient — the ``grdLib`` analogue (Guardian §4.1).

The paper preloads a shim library that shadows the CUDA *runtime + driver*
APIs and forwards every call to the grdManager over IPC.  Here the tenant
holds a ``GuardianClient`` whose methods are the device API surface:

    malloc / free                  (cudaMalloc / cudaFree)
    memcpy_h2d / d2h / d2d         (cudaMemcpy family)
    launch_kernel                  (cudaLaunchKernel)
    synchronize                    (cudaDeviceSynchronize)
    module_load                    (cuModuleLoadData — driver API)

Tenants never see an arena buffer — only opaque :class:`DevicePtr` handles.
Every call is appended to a :class:`CallTrace` with nanosecond timestamps,
which is how we reproduce the paper's Table 5 (interception cost) and
Table 6 (implicit calls from closed-source libraries).

Security note (paper §5 "Bypass Guardian checks"): the client owns no device
state; even a forged ``DevicePtr`` is re-validated by the manager against
the partition bounds table before any transfer, and kernel-borne indices are
fenced inside the sandboxed kernels regardless of what the client claims.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DevicePtr:
    """Opaque device-memory handle: absolute slot address + length.

    Like a raw CUDA pointer this is *forgeable* by a malicious tenant
    (``dataclasses.replace(ptr, addr=...)``) — the manager treats it as
    untrusted input and validates it on every use.

    ``epoch`` stamps which *elastic relocation epoch* of the tenant's
    partition minted the handle: the manager's pointer translation is
    keyed per epoch, so an address reused by a later extent never
    aliases a stale handle's translation (see
    ``GuardianManager._resolve_ptr``).  Forging it only selects a
    different translation table — the result is bounds-validated like
    any address.
    """

    tenant_id: str
    addr: int        # absolute slot index in the flat arena
    length: int      # slots
    epoch: int = 0   # elastic relocation epoch at mint time

    @property
    def end(self) -> int:
        return self.addr + self.length

    @property
    def addr_device(self):
        """Device-staged int32 of .addr, cached (launch fast path)."""
        try:
            return object.__getattribute__(self, "_addr_dev")
        except AttributeError:
            import jax.numpy as jnp
            val = jnp.int32(self.addr)
            object.__setattr__(self, "_addr_dev", val)
            return val


@dataclasses.dataclass
class CallRecord:
    api: str                 # e.g. "cudaMalloc", "cuLaunchKernel"
    level: str               # "runtime" | "driver"
    tenant_id: str
    detail: str = ""
    t_start_ns: int = 0
    t_end_ns: int = 0
    implicit_of: Optional[str] = None   # high-level library call that caused it

    @property
    def duration_ns(self) -> int:
        return self.t_end_ns - self.t_start_ns


class CallTrace:
    """Per-client call log — Tables 5/6 are computed from this."""

    def __init__(self):
        self.records: List[CallRecord] = []
        self._implicit_ctx: List[str] = []

    def push_context(self, highlevel_call: str) -> None:
        self._implicit_ctx.append(highlevel_call)

    def pop_context(self) -> None:
        self._implicit_ctx.pop()

    def record(self, api: str, level: str, tenant_id: str,
               detail: str = "") -> CallRecord:
        rec = CallRecord(
            api=api, level=level, tenant_id=tenant_id, detail=detail,
            t_start_ns=time.perf_counter_ns(),
            implicit_of=self._implicit_ctx[-1] if self._implicit_ctx else None,
        )
        self.records.append(rec)
        return rec

    def implicit_calls(self) -> Dict[str, Dict[str, int]]:
        """{high-level call: {api: count}} — the paper's Table 6."""
        out: Dict[str, Dict[str, int]] = {}
        for r in self.records:
            if r.implicit_of is None:
                continue
            out.setdefault(r.implicit_of, {})
            out[r.implicit_of][r.api] = out[r.implicit_of].get(r.api, 0) + 1
        return out

    def api_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.api] = out.get(r.api, 0) + 1
        return out


class GuardianClient:
    """The tenant-side device API.  All methods forward to the manager."""

    def __init__(self, manager, tenant_id: str):
        self._manager = manager
        self.tenant_id = tenant_id
        self.trace = CallTrace()

    # ------------------------------------------------------------------ #
    # CUDA-runtime-level surface                                         #
    # ------------------------------------------------------------------ #
    def malloc(self, n_slots: int) -> DevicePtr:
        rec = self.trace.record("cudaMalloc", "runtime", self.tenant_id,
                                f"n={n_slots}")
        ptr = self._manager.malloc(self.tenant_id, n_slots)
        rec.t_end_ns = time.perf_counter_ns()
        return ptr

    def free(self, ptr: DevicePtr) -> None:
        rec = self.trace.record("cudaFree", "runtime", self.tenant_id,
                                f"addr={ptr.addr}")
        self._manager.free(self.tenant_id, ptr)
        rec.t_end_ns = time.perf_counter_ns()

    def memcpy_h2d(self, ptr: DevicePtr, host: np.ndarray) -> None:
        rec = self.trace.record("cudaMemcpyH2D", "runtime", self.tenant_id,
                                f"addr={ptr.addr} n={host.size}")
        self._manager.memcpy_h2d(self.tenant_id, ptr, host)
        rec.t_end_ns = time.perf_counter_ns()

    def memcpy_d2h(self, ptr: DevicePtr, n_slots: Optional[int] = None
                   ) -> np.ndarray:
        rec = self.trace.record("cudaMemcpyD2H", "runtime", self.tenant_id,
                                f"addr={ptr.addr}")
        out = self._manager.memcpy_d2h(self.tenant_id, ptr, n_slots)
        rec.t_end_ns = time.perf_counter_ns()
        return out

    def memcpy_d2d(self, dst: DevicePtr, src: DevicePtr,
                   n_slots: int) -> None:
        rec = self.trace.record("cudaMemcpyD2D", "runtime", self.tenant_id,
                                f"dst={dst.addr} src={src.addr} n={n_slots}")
        self._manager.memcpy_d2d(self.tenant_id, dst, src, n_slots)
        rec.t_end_ns = time.perf_counter_ns()

    def launch_kernel(self, name: str, ptrs: Sequence[DevicePtr] = (),
                      args: Sequence[Any] = (), enqueue: bool = False) -> Any:
        """cudaLaunchKernel: the manager looks up the sandboxed twin in its
        pointerToSymbol map, augments the parameter list with (base, mask)
        and issues it (§4.2.3)."""
        rec = self.trace.record("cudaLaunchKernel", "runtime", self.tenant_id,
                                f"kernel={name}")
        out = self._manager.launch_kernel(self.tenant_id, name, ptrs, args,
                                          enqueue=enqueue)
        rec.t_end_ns = time.perf_counter_ns()
        return out

    def synchronize(self) -> None:
        rec = self.trace.record("cudaDeviceSynchronize", "runtime",
                                self.tenant_id)
        self._manager.synchronize(self.tenant_id)
        rec.t_end_ns = time.perf_counter_ns()

    # ------------------------------------------------------------------ #
    # CUDA-driver-level surface                                          #
    # ------------------------------------------------------------------ #
    def module_load(self, name: str, fn, arena_argnums=(0,),
                    verify: bool = True,
                    fence_aware: bool = False) -> None:
        """cuModuleLoadData: register a kernel.  The manager sandboxes and
        pre-compiles it (paper: 'compiles the sandboxed PTXs at its
        initialization avoiding JIT overhead at runtime').

        ``verify=False`` skips the static bounds verifier: no fences are
        elided and provably out-of-bounds kernels are contained at run
        time instead of refused at trace time."""
        rec = self.trace.record("cuModuleLoadData", "driver", self.tenant_id,
                                f"module={name}")
        self._manager.register_kernel(name, fn, arena_argnums,
                                      verify=verify,
                                      fence_aware=fence_aware)
        rec.t_end_ns = time.perf_counter_ns()

    def event_create(self) -> None:
        rec = self.trace.record("cudaEventCreateWithFlags", "runtime",
                                self.tenant_id)
        rec.t_end_ns = time.perf_counter_ns()

    def event_record(self) -> None:
        rec = self.trace.record("cudaEventRecord", "runtime", self.tenant_id)
        rec.t_end_ns = time.perf_counter_ns()

    def stream_get_capture_info(self) -> None:
        rec = self.trace.record("cudaStreamGetCaptureInfo", "runtime",
                                self.tenant_id)
        rec.t_end_ns = time.perf_counter_ns()

    # cudaGetExportTable analogue: undocumented entry points that big
    # frameworks hit; we expose a minimal table (paper §4.1 second challenge).
    def get_export_table(self, table_id: int) -> Dict[str, Any]:
        rec = self.trace.record("cudaGetExportTable", "runtime",
                                self.tenant_id, f"table={table_id}")
        rec.t_end_ns = time.perf_counter_ns()
        return self._manager.export_table(table_id)
