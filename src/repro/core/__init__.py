"""Guardian core: the paper's contribution as composable JAX modules.

Layering (bottom-up):

    partition   — pow2 buddy arena allocator + partition bounds table
    fence       — the 3 bounds modes (bitwise / modulo / check) + guarded ops
    arena       — shared device arenas (flat DRAM model + structured pools)
    violations  — ViolationLog: device-side per-tenant per-kind OOB counters
    sandbox     — jaxpr-level kernel instrumentor (the "PTX-patcher")
    interception— GuardianClient ("grdLib"): device-API shadowing + traces
    scheduler   — BatchedLaunchScheduler: coalesces compatible cross-tenant
                  launches into fused device steps (per-row fence tables);
                  CHECK batches attribute per-row ok + commit selectively
    quarantine  — tenant lifecycle (ACTIVE→QUARANTINED→EVICTED|READMITTED),
                  pluggable thresholds, partition reclamation, automatic
                  readmission probes (probation partitions)
    pressure    — host-side allocation-pressure telemetry (EWMA +
                  watermarks, dirty-flag gated) feeding elastic + the
                  scheduler's adaptive lookahead
    elastic     — ElasticManager: admission waitlist, live partition
                  grow/shrink, on-device compaction (dynamic spatial
                  sharing; WAITLISTED→ACTIVE→RESIZING→COMPACTING)
    telemetry   — flight recorder: per-tenant metrics registry (counters/
                  gauges/histograms) + lifecycle event trace with
                  Chrome/Perfetto export; fed at drain-cycle boundaries,
                  never a device sync
    tenantclass — SLO classes (latency_critical / best_effort): per-class
                  lookahead + queue-age budgets driving best-effort
                  preemption, compute-aware admission, and per-tenant
                  quarantine thresholds, in one TenantClassPolicy
    manager     — GuardianManager ("grdManager"): sole device owner,
                  validated calls, round-robin spatial multiplexing
    libsim      — simulated closed-source accelerated libraries (Table 6)
"""

from repro.core.arena import Arena, ArenaSpec, make_flat_arena
from repro.core.elastic import (
    Admission,
    AdmissionStatus,
    ElasticError,
    ElasticManager,
    ElasticPolicy,
    ElasticState,
    ResizeEvent,
)
from repro.core.pressure import (
    Ewma,
    PressureTracker,
    derive_lookahead,
    total_arrival_rate,
)
from repro.core.tenantclass import (
    TenantClass,
    TenantClassPolicy,
    as_class_policy,
)
from repro.core.fence import (
    FenceParams,
    FencePolicy,
    FenceTable,
    apply_fence,
    apply_fence_mixed,
    fence_bitwise,
    fence_check,
    fence_modulo,
    fence_modulo_magic,
    fence_modulo_magic_dyn,
    guarded_take,
    guarded_update,
    magic_constants,
    magic_row,
    require_pow2_sizes,
)
from repro.core.telemetry import (
    EventTrace,
    Histogram,
    MetricsRegistry,
    Telemetry,
    TraceEvent,
)
from repro.core.scheduler import (
    BatchedLaunchScheduler,
    LaunchRequest,
    LRUCache,
    SchedulerStats,
    round_robin_interleave,
)
from repro.core.interception import CallTrace, DevicePtr, GuardianClient
from repro.core.manager import (
    GuardianManager,
    GuardianViolation,
    SharingMode,
)
from repro.core.partition import (
    BuddyAllocator,
    OutOfArenaMemory,
    Partition,
    PartitionBoundsTable,
    UnknownTenant,
)
from repro.core.quarantine import (
    QuarantineError,
    QuarantineManager,
    QuarantinePolicy,
    QuarantineStateMachine,
    TenantQuarantined,
    TenantState,
    ThresholdPolicy,
    WeightedRatePolicy,
)
from repro.core.sandbox import SandboxError, sandbox, sandbox_report
from repro.core.violations import (
    KIND_NAMES,
    NUM_KINDS,
    ViolationKind,
    ViolationLog,
)

__all__ = [
    "Arena", "ArenaSpec", "make_flat_arena",
    "Admission", "AdmissionStatus", "ElasticError", "ElasticManager",
    "ElasticPolicy", "ElasticState", "ResizeEvent",
    "Ewma", "PressureTracker", "derive_lookahead", "total_arrival_rate",
    "TenantClass", "TenantClassPolicy", "as_class_policy",
    "FenceParams", "FencePolicy", "FenceTable", "apply_fence",
    "apply_fence_mixed", "fence_bitwise", "fence_check", "fence_modulo",
    "fence_modulo_magic", "fence_modulo_magic_dyn",
    "guarded_take", "guarded_update", "magic_constants", "magic_row",
    "require_pow2_sizes",
    "EventTrace", "Histogram", "MetricsRegistry", "Telemetry",
    "TraceEvent",
    "BatchedLaunchScheduler", "LaunchRequest", "LRUCache",
    "SchedulerStats", "round_robin_interleave",
    "CallTrace", "DevicePtr", "GuardianClient",
    "GuardianManager", "GuardianViolation", "SharingMode",
    "BuddyAllocator", "OutOfArenaMemory", "Partition",
    "PartitionBoundsTable", "UnknownTenant",
    "SandboxError", "sandbox", "sandbox_report",
    "KIND_NAMES", "NUM_KINDS", "ViolationKind", "ViolationLog",
    "QuarantineError", "QuarantineManager", "QuarantinePolicy",
    "QuarantineStateMachine", "TenantQuarantined", "TenantState",
    "ThresholdPolicy", "WeightedRatePolicy",
]
