"""Guardian core: the paper's contribution as composable JAX modules.

Layering (bottom-up):

    partition   — pow2 buddy arena allocator + partition bounds table
    fence       — the 3 bounds modes (bitwise / modulo / check) + guarded ops
    arena       — shared device arenas (flat DRAM model + structured pools)
    sandbox     — jaxpr-level kernel instrumentor (the "PTX-patcher")
    interception— GuardianClient ("grdLib"): device-API shadowing + traces
    scheduler   — BatchedLaunchScheduler: coalesces compatible cross-tenant
                  launches into fused device steps (per-row fence tables)
    manager     — GuardianManager ("grdManager"): sole device owner,
                  validated calls, round-robin spatial multiplexing
    libsim      — simulated closed-source accelerated libraries (Table 6)
"""

from repro.core.arena import Arena, ArenaSpec, make_flat_arena
from repro.core.fence import (
    FenceParams,
    FencePolicy,
    FenceTable,
    apply_fence,
    fence_bitwise,
    fence_check,
    fence_modulo,
    fence_modulo_magic,
    guarded_take,
    guarded_update,
    magic_constants,
    require_pow2_sizes,
)
from repro.core.scheduler import (
    BatchedLaunchScheduler,
    LaunchRequest,
    SchedulerStats,
)
from repro.core.interception import CallTrace, DevicePtr, GuardianClient
from repro.core.manager import (
    GuardianManager,
    GuardianViolation,
    SharingMode,
)
from repro.core.partition import (
    BuddyAllocator,
    OutOfArenaMemory,
    Partition,
    PartitionBoundsTable,
    UnknownTenant,
)
from repro.core.sandbox import SandboxError, sandbox, sandbox_report

__all__ = [
    "Arena", "ArenaSpec", "make_flat_arena",
    "FenceParams", "FencePolicy", "FenceTable", "apply_fence",
    "fence_bitwise", "fence_check", "fence_modulo", "fence_modulo_magic",
    "guarded_take", "guarded_update", "magic_constants",
    "require_pow2_sizes",
    "BatchedLaunchScheduler", "LaunchRequest", "SchedulerStats",
    "CallTrace", "DevicePtr", "GuardianClient",
    "GuardianManager", "GuardianViolation", "SharingMode",
    "BuddyAllocator", "OutOfArenaMemory", "Partition",
    "PartitionBoundsTable", "UnknownTenant",
    "SandboxError", "sandbox", "sandbox_report",
]
