"""Simulated closed-source CUDA-accelerated libraries (Guardian §4.1, §7.7).

The paper's hardest interception case: high-level library calls
(``cublasIsamax`` et al.) *implicitly* issue CUDA runtime/driver calls —
mallocs, copies, kernel launches — that must not escape the manager.

These classes model that behaviour: each high-level entry point performs the
same implicit call pattern the paper measured (Table 6), all through the
tenant's :class:`GuardianClient`, so the trace reproduces the table and the
kernels inside run sandboxed.  The kernel bodies are registered at
``create()`` time via ``module_load`` (the paper extracts and patches the
PTX of the library offline; we register-and-sandbox the jaxprs up front).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interception import DevicePtr, GuardianClient


# --------------------------------------------------------------------------
# "Library" kernels: signature fn(arena, *args) -> (new_arena, out).
# They address device memory via raw integer slot offsets (ptrs) — exactly
# the unsafe pattern the sandboxer must fence.
# --------------------------------------------------------------------------

def _k_isamax(arena, x_ptr, n: int):
    idx = x_ptr + jnp.arange(n, dtype=jnp.int32)
    x = jnp.take(arena, idx, axis=0)
    return arena, jnp.argmax(jnp.abs(x)).astype(jnp.int32)


def _k_dot(arena, x_ptr, y_ptr, out_ptr, n: int):
    ii = jnp.arange(n, dtype=jnp.int32)
    x = jnp.take(arena, x_ptr + ii, axis=0)
    y = jnp.take(arena, y_ptr + ii, axis=0)
    d = jnp.dot(x, y)
    arena = arena.at[out_ptr].set(d)
    return arena, d


def _k_axpby(arena, x_ptr, y_ptr, alpha, beta, n: int):
    ii = jnp.arange(n, dtype=jnp.int32)
    x = jnp.take(arena, x_ptr + ii, axis=0)
    y = jnp.take(arena, y_ptr + ii, axis=0)
    arena = arena.at[y_ptr + ii].set(alpha * x + beta * y)
    return arena, None


def _k_gemm(arena, a_ptr, b_ptr, c_ptr, m: int, k: int, n: int):
    ii = jnp.arange(m * k, dtype=jnp.int32)
    jj = jnp.arange(k * n, dtype=jnp.int32)
    a = jnp.take(arena, a_ptr + ii, axis=0).reshape(m, k)
    b = jnp.take(arena, b_ptr + jj, axis=0).reshape(k, n)
    c = (a @ b).reshape(-1)
    oo = jnp.arange(m * n, dtype=jnp.int32)
    arena = arena.at[c_ptr + oo].set(c)
    return arena, None


def _k_fft_c2c(arena, x_ptr, out_ptr, n: int):
    """Complex-interleaved FFT: 2n real slots in, 2n real slots out."""
    ii = jnp.arange(2 * n, dtype=jnp.int32)
    buf = jnp.take(arena, x_ptr + ii, axis=0)
    z = jax.lax.complex(buf[0::2], buf[1::2])
    f = jnp.fft.fft(z)
    inter = jnp.stack([jnp.real(f), jnp.imag(f)], axis=-1).reshape(-1)
    arena = arena.at[out_ptr + ii].set(inter)
    return arena, None


def _k_csr_spmv(arena, vals_ptr, cols_ptr, x_ptr, y_ptr, nnz: int, n: int):
    """Sparse matvec where the *column indices live in device memory* —
    a data-dependent gather whose indices are themselves tenant data.  This
    is the paper's nastiest case: the address register is loaded from
    memory before the ld.global."""
    kk = jnp.arange(nnz, dtype=jnp.int32)
    vals = jnp.take(arena, vals_ptr + kk, axis=0)
    cols = jnp.take(arena, cols_ptr + kk, axis=0).astype(jnp.int32)
    xs = jnp.take(arena, x_ptr + cols, axis=0)   # double indirection
    prod = vals * xs
    rows = kk % n
    y = jnp.zeros((n,), arena.dtype).at[rows].add(prod)
    oo = jnp.arange(n, dtype=jnp.int32)
    arena = arena.at[y_ptr + oo].set(y)
    return arena, None


class GrdBLAS:
    """cuBLAS stand-in.  Mirrors the implicit-call patterns of Table 6."""

    def __init__(self, client: GuardianClient):
        self.client = client
        self._workspace: Optional[DevicePtr] = None

    def create(self) -> "GrdBLAS":
        """cublasCreate: 3 mallocs, 18 event-creates, 2 frees, a launch and
        a memcpy (Table 6 row 1)."""
        c = self.client
        c.trace.push_context("cublasCreate")
        try:
            ws = [c.malloc(16) for _ in range(3)]
            for _ in range(18):
                c.event_create()
            c.free(ws[1])
            c.free(ws[2])
            c.memcpy_h2d(ws[0], np.zeros(16, np.float32))
            c.launch_kernel("grdblas.init", ptrs=[ws[0]], args=(16,))
            self._workspace = ws[0]
        finally:
            c.trace.pop_context()
        return self

    def isamax(self, x: DevicePtr, n: int) -> int:
        c = self.client
        c.trace.push_context("cublasIsamax")
        try:
            c.stream_get_capture_info()
            c.stream_get_capture_info()
            out = c.launch_kernel("grdblas.isamax", ptrs=[x], args=(n,))
            c.event_record()
            c.synchronize()
            res = c.memcpy_d2h(x, 0)  # result fetch (0-slot marker read)
            del res
        finally:
            c.trace.pop_context()
        c._manager.run_queued()
        return out

    def dot(self, x: DevicePtr, y: DevicePtr, out: DevicePtr, n: int):
        c = self.client
        c.trace.push_context("cublasDdot")
        try:
            c.stream_get_capture_info()
            c.stream_get_capture_info()
            c.launch_kernel("grdblas.dot_pre", ptrs=[x], args=(n,))
            res = c.launch_kernel("grdblas.dot", ptrs=[x, y, out], args=(n,))
            c.event_record()
            c.memcpy_d2h(out, 1)
        finally:
            c.trace.pop_context()
        return res

    def axpby(self, alpha: float, x: DevicePtr, beta: float, y: DevicePtr,
              n: int) -> None:
        c = self.client
        c.trace.push_context("cublasAxpby")
        try:
            c.launch_kernel("grdblas.axpby", ptrs=[x, y],
                            args=(jnp.float32(alpha), jnp.float32(beta), n),
                            )
        finally:
            c.trace.pop_context()

    def gemm(self, a: DevicePtr, b: DevicePtr, out: DevicePtr,
             m: int, k: int, n: int) -> None:
        c = self.client
        c.trace.push_context("cublasSgemm")
        try:
            c.stream_get_capture_info()
            c.launch_kernel("grdblas.gemm", ptrs=[a, b, out], args=(m, k, n))
        finally:
            c.trace.pop_context()

    @staticmethod
    def register_kernels(manager) -> None:
        manager.register_kernel("grdblas.init",
                                lambda arena, p, n: (arena, None))
        manager.register_kernel("grdblas.isamax", _k_isamax)
        manager.register_kernel("grdblas.dot_pre",
                                lambda arena, p, n: (arena, None))
        manager.register_kernel("grdblas.dot", _k_dot)
        manager.register_kernel("grdblas.axpby", _k_axpby)
        manager.register_kernel("grdblas.gemm", _k_gemm)


class GrdFFT:
    """cuFFT stand-in (Table 6 ``cufftExecC2C`` row: 2 H2D, alloc, free,
    launch, stream query)."""

    def __init__(self, client: GuardianClient):
        self.client = client

    def exec_c2c(self, x: DevicePtr, out: DevicePtr, n: int) -> None:
        c = self.client
        c.trace.push_context("cufftExecC2C")
        try:
            plan = c.malloc(8)                       # cuMemAlloc
            c.memcpy_h2d(plan, np.zeros(8, np.float32))   # cuMemcpyHtoD x2
            c.memcpy_h2d(plan, np.ones(8, np.float32))
            c.stream_get_capture_info()              # cudaStreamIsCapturing
            c.launch_kernel("grdfft.c2c", ptrs=[x, out], args=(n,))
            c.free(plan)                             # cuMemFree
        finally:
            c.trace.pop_context()

    @staticmethod
    def register_kernels(manager) -> None:
        manager.register_kernel("grdfft.c2c", _k_fft_c2c)


class GrdSPARSE:
    """cuSPARSE stand-in — the double-indirection SpMV is the adversarial
    showcase: column indices are tenant-controlled device data."""

    def __init__(self, client: GuardianClient):
        self.client = client

    def csr_spmv(self, vals: DevicePtr, cols: DevicePtr, x: DevicePtr,
                 y: DevicePtr, nnz: int, n: int) -> None:
        c = self.client
        c.trace.push_context("cusparseSpMV")
        try:
            c.stream_get_capture_info()
            c.launch_kernel("grdsparse.csr_spmv",
                            ptrs=[vals, cols, x, y], args=(nnz, n))
            c.launch_kernel("grdsparse.csr_spmv_post", ptrs=[y], args=(n,))
        finally:
            c.trace.pop_context()

    @staticmethod
    def register_kernels(manager) -> None:
        manager.register_kernel("grdsparse.csr_spmv", _k_csr_spmv)
        manager.register_kernel("grdsparse.csr_spmv_post",
                                lambda arena, p, n: (arena, None))


def register_all_libraries(manager) -> None:
    GrdBLAS.register_kernels(manager)
    GrdFFT.register_kernels(manager)
    GrdSPARSE.register_kernels(manager)
