"""Jaxpr-level kernel sandboxing — the "PTX-patcher" analogue (Guardian §4.3).

The paper instruments the *virtual assembly* (PTX) of every GPU kernel —
including kernels inside closed-source libraries — inserting fence
instructions before every load/store.  The JAX analogue of "a kernel you
cannot modify at source level" is a **traced jaxpr**: third-party callables
are opaque Python, but their jaxpr is always available (the same way PTX is
always embedded for forward compatibility).

``sandbox(fn, arena_argnums)`` walks the traced jaxpr of ``fn`` and rewrites
every *data-dependent access into an arena-derived operand*:

    gather / scatter(-add/-mul/-min/-max) ........ fence the index columns
                                                    that address slot dim 0
    dynamic_slice / dynamic_update_slice ......... fence + pin the dim-0 start

Static accesses (``slice``, constant indices) are proven in-bounds by XLA at
compile time — the exact analogue of the paper treating direct branches as
safe while fencing register-addressed loads.  Indexing into *tenant-private*
tensors cannot reach the arena (separate XLA buffers, clamped OOB), matching
the paper's observation that host memory is safe via process isolation.

Taint tracking mirrors "which PTX register holds a global pointer": an
operand is fenced iff it is the arena argument or derived from it through
layout-preserving ops.  ``reshape``/``transpose`` that destroy the slot
dim 0 *keep* taint conservatively and emit a
:class:`~repro.core.verifier.GuardianTaintWarning` (containment over
precision — never silently launder the arena lineage).  Scatter outputs
remain tainted (the arena flows through); gather outputs are *values*, not
slot space, so taint stops there.

Call primitives (``jit``/``pjit``, ``custom_jvp/vjp``, ``remat``,
``closed_call``) are interpreted recursively, so fences land inside library
wrappers — the paper's "implicit calls of cuBLAS" case.  ``scan``/``while``/
``cond`` with arena-derived operands are **interpreted structurally**: loop
bodies are re-traced with fences inside, carry taints resolved by the
verifier's monotone fixpoint (:func:`repro.core.verifier.loop_carry_taints`),
and CHECK ``ok``/count payloads threaded through the carried state /
stacked outputs.  Rejection (:class:`SandboxError`) remains only for the
cases the fixpoint cannot close (non-converging carries, CHECK predicates
inside a ``while`` condition, where the ok cannot escape the cond jaxpr).

With ``verify=True`` the sandbox additionally runs the static bounds
verifier (:mod:`repro.core.verifier`) over the same jaxpr and consumes the
resulting :class:`~repro.core.verifier.SandboxProof`:

    PROVEN sites ... fence **elided** (the compiler guarantee replaces the
                     runtime instruction — Guardian's direct-access case)
    FENCED sites ... fenced exactly as before
    REFUTED sites .. :class:`~repro.core.verifier.GuardianStaticViolation`
                     at trace time with the per-site diagnostic
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.extend.core as jex_core
import numpy as np
import jax.numpy as jnp

from repro.core.fence import FenceParams, FencePolicy, apply_fence
from repro.core.violations import NUM_KINDS, ViolationKind
from repro.core.verifier import (      # shared tables: the two walkers must
    _CALL_PRIMS,                       # classify taint identically
    _LOOP_PRIMS,
    _SCATTER_PRIMS,
    _TAINT_TRANSPARENT,
    PROVEN,
    GuardianStaticViolation,
    GuardianTaintWarning,
    SandboxProof,
    VerifierError,
    loop_carry_taints,
    refute_message,
    transparent_taint,
    verify_jaxpr,
)

__all__ = [
    "SandboxError", "SandboxReport", "sandbox", "sandbox_report",
    "GuardianStaticViolation", "GuardianTaintWarning",
]


class SandboxError(Exception):
    """Raised when a tenant kernel uses a construct the sandboxer cannot
    prove safe (the manager refuses the kernel at registration time —
    fail-closed, like grdManager refusing an unknown CUDA symbol)."""


@dataclasses.dataclass
class SandboxReport:
    """What the patcher did — Table 3 analogue (#loads/#stores safeguarded).

    ``elided_*`` counts are sites the static verifier PROVED in-bounds so
    no fence was emitted (only nonzero under ``verify=True``)."""

    fenced_gathers: int = 0
    fenced_scatters: int = 0
    fenced_dynamic_slices: int = 0
    fenced_dynamic_updates: int = 0
    elided_gathers: int = 0
    elided_scatters: int = 0
    elided_dynamic_slices: int = 0
    elided_dynamic_updates: int = 0
    total_eqns: int = 0
    proof: Optional[SandboxProof] = None

    @property
    def fenced_total(self) -> int:
        return (self.fenced_gathers + self.fenced_scatters
                + self.fenced_dynamic_slices + self.fenced_dynamic_updates)

    @property
    def elided_total(self) -> int:
        return (self.elided_gathers + self.elided_scatters
                + self.elided_dynamic_slices + self.elided_dynamic_updates)

    def merge(self, other: "SandboxReport") -> None:
        for f in ("fenced_gathers", "fenced_scatters",
                  "fenced_dynamic_slices", "fenced_dynamic_updates",
                  "elided_gathers", "elided_scatters",
                  "elided_dynamic_slices", "elided_dynamic_updates",
                  "total_eqns"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class _OkAcc:
    """CHECK-predicate accumulator.

    Raw per-site ``ok`` element arrays are tagged with their access kind so
    the caller can both reduce to a scalar verdict and count violating
    elements per kind; loop bodies contribute pre-reduced ``(ok, counts)``
    pairs threaded out through the loop's carried state / stacked outputs.
    """

    def __init__(self):
        self._raw: List[Tuple[ViolationKind, jax.Array]] = []
        self._reduced: List[Tuple[jax.Array, jax.Array]] = []

    def add(self, kind: ViolationKind, ok: jax.Array) -> None:
        self._raw.append((kind, ok))

    def add_reduced(self, ok: jax.Array, counts: jax.Array) -> None:
        self._reduced.append((ok, counts))

    @property
    def empty(self) -> bool:
        return not self._raw and not self._reduced

    def ok(self) -> jax.Array:
        parts = [jnp.all(o) for _, o in self._raw]
        parts += [jnp.all(o) for o, _ in self._reduced]
        if not parts:
            return jnp.bool_(True)
        return jnp.all(jnp.stack(parts))

    def counts(self) -> jax.Array:
        c = jnp.zeros((NUM_KINDS,), jnp.int32)
        for kind, o in self._raw:
            n_bad = jnp.sum(jnp.logical_not(o).astype(jnp.int32))
            c = c.at[int(kind)].add(n_bad)
        for _, cv in self._reduced:
            c = c + jnp.sum(jnp.asarray(cv, jnp.int32).reshape(
                (-1, NUM_KINDS)), axis=0)
        return c


def _read(env: Dict[Any, Any], v) -> Any:
    if isinstance(v, jex_core.Literal):
        return v.val
    return env[v]


def _is_tainted(taint: Dict[Any, bool], v) -> bool:
    if isinstance(v, jex_core.Literal):
        return False
    return taint.get(v, False)


def _fence_index_columns(
    indices: jax.Array,
    cols: Sequence[int],
    params: FenceParams,
    policy: FencePolicy,
    oks: _OkAcc,
    kind: ViolationKind,
) -> jax.Array:
    """Fence the given trailing-dim columns of a gather/scatter index array.

    CHECK-mode ``ok`` predicates are collected *untruncated* (full element
    arrays, tagged with the access kind) so the caller can both reduce them
    to a scalar verdict and count the violating elements per kind."""
    if indices.ndim == 0:
        fenced, ok = apply_fence(policy, indices, params)
        if ok is not None:
            oks.add(kind, ok)
        return fenced.astype(indices.dtype)
    out = indices
    for c in cols:
        col = indices[..., c]
        fenced, ok = apply_fence(policy, col, params)
        if ok is not None:
            oks.add(kind, ok)
        out = out.at[..., c].set(fenced.astype(indices.dtype))
    return out


def _proven(verdicts: Optional[Dict[Tuple, str]], site: Tuple) -> bool:
    return verdicts is not None and verdicts.get(site) == PROVEN


def _interpret(
    closed: Any,  # ClosedJaxpr
    args: Sequence[Any],
    tainted_in: Sequence[bool],
    params: FenceParams,
    policy: FencePolicy,
    report: SandboxReport,
    oks: _OkAcc,
    verdicts: Optional[Dict[Tuple, str]] = None,
    path: Tuple = (),
) -> Tuple[List[Any], List[bool]]:
    jaxpr = closed.jaxpr
    env: Dict[Any, Any] = {}
    taint: Dict[Any, bool] = {}

    for var, val in zip(jaxpr.constvars, closed.consts):
        env[var] = val
        taint[var] = False
    assert len(jaxpr.invars) == len(args), (len(jaxpr.invars), len(args))
    for var, val, t in zip(jaxpr.invars, args, tainted_in):
        env[var] = val
        taint[var] = t

    for i, eqn in enumerate(jaxpr.eqns):
        report.total_eqns += 1
        name = eqn.primitive.name
        invals = [_read(env, v) for v in eqn.invars]
        intaints = [_is_tainted(taint, v) for v in eqn.invars]
        site = (*path, i)

        out_taint = False

        if name in _CALL_PRIMS:
            sub = eqn.params.get(_CALL_PRIMS[name])
            if sub is None:  # fall back to any ClosedJaxpr-valued param
                sub = next(v for v in eqn.params.values()
                           if hasattr(v, "jaxpr"))
            outvals, out_taints = _interpret(sub, invals, intaints, params,
                                             policy, report, oks, verdicts,
                                             site)
            for var, val, t in zip(eqn.outvars, outvals, out_taints):
                env[var] = val
                taint[var] = t
            continue

        if name in _LOOP_PRIMS and any(intaints):
            try:
                outvals, out_taints = _interpret_loop(
                    eqn, invals, intaints, params, policy, report, oks,
                    verdicts, site)
            except VerifierError as e:
                raise SandboxError(
                    f"tenant kernel routes the shared arena through "
                    f"`{name}` and the carry fixpoint did not converge: {e}"
                ) from e
            for var, val, t in zip(eqn.outvars, outvals, out_taints):
                env[var] = val
                taint[var] = t
            continue

        if name == "gather" and intaints[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in enumerate(dnums.start_index_map) if d == 0]
            if cols:
                if _proven(verdicts, site):
                    report.elided_gathers += 1
                else:
                    invals = list(invals)
                    invals[1] = _fence_index_columns(
                        jnp.asarray(invals[1]), cols, params, policy, oks,
                        ViolationKind.GATHER)
                    report.fenced_gathers += 1
            out_taint = False  # gathered *values*, not slot space

        elif name in _SCATTER_PRIMS and intaints[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in
                    enumerate(dnums.scatter_dims_to_operand_dims) if d == 0]
            if cols:
                if _proven(verdicts, site):
                    report.elided_scatters += 1
                else:
                    invals = list(invals)
                    invals[1] = _fence_index_columns(
                        jnp.asarray(invals[1]), cols, params, policy, oks,
                        ViolationKind.SCATTER)
                    report.fenced_scatters += 1
            out_taint = True  # the arena flows through a scatter

        elif name == "dynamic_slice" and intaints[0]:
            if _proven(verdicts, site):
                report.elided_dynamic_slices += 1
            else:
                sizes = eqn.params["slice_sizes"]
                invals = list(invals)
                start0, ok = apply_fence(policy, jnp.asarray(invals[1]),
                                         params)
                if ok is not None:
                    oks.add(ViolationKind.SLICE, ok)
                hi = jnp.maximum(
                    jnp.asarray(params.base + params.size - sizes[0],
                                jnp.int32),
                    jnp.asarray(params.base, jnp.int32))
                invals[1] = jnp.minimum(start0, hi).astype(
                    jnp.asarray(invals[1]).dtype)
                report.fenced_dynamic_slices += 1
            out_taint = False

        elif name == "dynamic_update_slice" and intaints[0]:
            if _proven(verdicts, site):
                report.elided_dynamic_updates += 1
            else:
                invals = list(invals)
                upd_len = jnp.shape(invals[1])[0] if jnp.ndim(invals[1]) \
                    else 1
                start0, ok = apply_fence(policy, jnp.asarray(invals[2]),
                                         params)
                if ok is not None:
                    oks.add(ViolationKind.UPDATE, ok)
                hi = jnp.maximum(
                    jnp.asarray(params.base + params.size - upd_len,
                                jnp.int32),
                    jnp.asarray(params.base, jnp.int32))
                invals[2] = jnp.minimum(start0, hi).astype(
                    jnp.asarray(invals[2]).dtype)
                report.fenced_dynamic_updates += 1
            out_taint = True

        elif name in _TAINT_TRANSPARENT and intaints[0]:
            out_taint = transparent_taint(name, eqn, jnp.shape(invals[0]))

        outvals = eqn.primitive.bind(*invals, **eqn.params)
        if not eqn.primitive.multiple_results:
            outvals = [outvals]
        for var, val in zip(eqn.outvars, outvals):
            env[var] = val
            taint[var] = out_taint

    outs = [_read(env, v) for v in jaxpr.outvars]
    out_taints = [_is_tainted(taint, v) for v in jaxpr.outvars]
    return outs, out_taints


def _interpret_loop(
    eqn,
    invals: Sequence[Any],
    intaints: Sequence[bool],
    params: FenceParams,
    policy: FencePolicy,
    report: SandboxReport,
    oks: _OkAcc,
    verdicts: Optional[Dict[Tuple, str]],
    site: Tuple,
) -> Tuple[List[Any], List[bool]]:
    """Structurally interpret a tainted ``scan``/``while``/``cond``.

    Bodies are re-traced with the sandbox's fences inside; carry taints
    come from the verifier's monotone fixpoint so they are stable across
    iterations.  CHECK ``ok``/count payloads are threaded out through the
    loop (stacked ys for scan, carried state for while, uniform branch
    outputs for cond) and folded into ``oks`` as reduced pairs.
    """
    name = eqn.primitive.name

    if name == "scan":
        body = eqn.params["jaxpr"]
        n_c = eqn.params["num_consts"]
        n_car = eqn.params["num_carry"]
        length = eqn.params["length"]
        reverse = eqn.params["reverse"]
        unroll = eqn.params.get("unroll", 1)
        car_ts, body_out_ts = loop_carry_taints(eqn, intaints)
        const_vals = list(invals[:n_c])
        carry0 = list(invals[n_c:n_c + n_car])
        xs_vals = list(invals[n_c + n_car:])
        const_ts = list(intaints[:n_c])
        xs_ts = list(intaints[n_c + n_car:])
        box: List = []

        def scan_body(carry, x):
            x = () if x is None else x
            acc = _OkAcc()
            rep = SandboxReport()
            outs, _ = _interpret(
                body, [*const_vals, *carry, *x],
                [*const_ts, *car_ts, *xs_ts], params, policy, rep, acc,
                verdicts, (*site, 0))
            box[:] = [(rep, acc.empty)]
            payload = () if acc.empty else (acc.ok(), acc.counts())
            return tuple(outs[:n_car]), (tuple(outs[n_car:]), payload)

        final_carry, (ys, payload) = jax.lax.scan(
            scan_body, tuple(carry0), tuple(xs_vals) or None,
            length=length, reverse=reverse, unroll=unroll)
        rep, _acc_empty = box[0]
        report.merge(rep)
        if payload:
            oks.add_reduced(payload[0], payload[1])
        return ([*final_carry, *ys],
                [*car_ts, *body_out_ts[n_car:]])

    if name == "while":
        cond_j = eqn.params["cond_jaxpr"]
        body_j = eqn.params["body_jaxpr"]
        n_cc = eqn.params["cond_nconsts"]
        n_bc = eqn.params["body_nconsts"]
        car_ts, _ = loop_carry_taints(eqn, intaints)
        cconst = list(invals[:n_cc])
        bconst = list(invals[n_cc:n_cc + n_bc])
        carry0 = list(invals[n_cc + n_bc:])
        cconst_ts = list(intaints[:n_cc])
        bconst_ts = list(intaints[n_cc:n_cc + n_bc])
        n_car = len(carry0)
        cond_box: List = []
        body_box: List = []

        def cond_fn(state):
            acc = _OkAcc()
            rep = SandboxReport()
            outs, _ = _interpret(
                cond_j, [*cconst, *state[:n_car]],
                [*cconst_ts, *car_ts], params, policy, rep, acc,
                verdicts, (*site, 0))
            cond_box[:] = [(rep, acc.empty)]
            return outs[0]

        def body_fn(state):
            acc = _OkAcc()
            rep = SandboxReport()
            outs, _ = _interpret(
                body_j, [*bconst, *state[:n_car]],
                [*bconst_ts, *car_ts], params, policy, rep, acc,
                verdicts, (*site, 1))
            body_box[:] = [rep]
            return (*outs,
                    jnp.logical_and(state[n_car], acc.ok()),
                    state[n_car + 1] + acc.counts())

        init = (*carry0, jnp.bool_(True),
                jnp.zeros((NUM_KINDS,), jnp.int32))
        out_state = jax.lax.while_loop(cond_fn, body_fn, init)
        cond_rep, cond_ok_empty = cond_box[0]
        if not cond_ok_empty:
            raise SandboxError(
                "tenant kernel fences a tainted access inside a `while` "
                "condition under CHECK policy; the ok predicate cannot "
                "escape the cond jaxpr — use a fencing policy or move the "
                "access into the loop body")
        report.merge(cond_rep)
        report.merge(body_box[0])
        oks.add_reduced(out_state[n_car], out_state[n_car + 1])
        return list(out_state[:n_car]), list(car_ts)

    if name == "cond":
        branches = eqn.params["branches"]
        pred = invals[0]
        ops = list(invals[1:])
        ops_ts = list(intaints[1:])
        boxes: List[List] = [[] for _ in branches]

        def mk(bidx, br):
            def branch_fn(*ops_in):
                acc = _OkAcc()
                rep = SandboxReport()
                outs, out_ts = _interpret(
                    br, list(ops_in), ops_ts, params, policy, rep, acc,
                    verdicts, (*site, bidx))
                boxes[bidx][:] = [(rep, out_ts)]
                return (*outs, acc.ok(), acc.counts())
            return branch_fn

        res = jax.lax.switch(
            pred, [mk(b, br) for b, br in enumerate(branches)], *ops)
        *outs, okv, cnts = res
        oks.add_reduced(okv, cnts)
        out_ts = None
        for box in boxes:
            rep, bts = box[0]
            report.merge(rep)
            out_ts = bts if out_ts is None else [
                a or b for a, b in zip(out_ts, bts)]
        return list(outs), list(out_ts or [])

    raise SandboxError(f"unsupported loop primitive `{name}`")


def _flat_taints(dyn_pos, dyn_args, arena_set):
    taints: List[bool] = []
    slots: Dict[int, Tuple[int, int]] = {}
    off = 0
    for p, a in zip(dyn_pos, dyn_args):
        n = len(jax.tree_util.tree_leaves(a))
        slots[p] = (off, off + n)
        taints.extend([p in arena_set] * n)
        off += n
    return taints, slots


def _run_verifier(
    closed, taints, slots, fence_params, bound_argnums, kernel_name,
):
    """Proof for a freshly traced kernel jaxpr; REFUTED -> trace-time
    violation.  Static rows give a concrete proof; traced rows give the
    symbolic (B, S) proof valid for every partition."""
    vparams = fence_params if (isinstance(fence_params, FenceParams)
                               and fence_params.is_static) else None
    n_in = len(closed.jaxpr.invars)
    in_roles: List[Optional[str]] = [None] * n_in
    for role, argnum in zip(("base", "mask"), bound_argnums):
        slot = slots.get(argnum)
        if slot is not None and slot[1] - slot[0] == 1:
            in_roles[slot[0]] = role
    arena_extent = None
    for i, t in enumerate(taints):
        if t and closed.jaxpr.invars[i].aval.shape:
            arena_extent = int(closed.jaxpr.invars[i].aval.shape[0])
            break
    try:
        proof = verify_jaxpr(closed, taints, vparams, in_roles=in_roles,
                             arena_extent=arena_extent, mode="row")
    except VerifierError as e:
        raise SandboxError(
            f"static verification of kernel {kernel_name!r} failed: {e}"
        ) from e
    if proof.n_refuted:
        raise GuardianStaticViolation(refute_message(proof, kernel_name))
    return proof


def sandbox(
    fn: Callable,
    arena_argnums: Sequence[int] = (0,),
    policy: FencePolicy = FencePolicy.BITWISE,
    count_violations: bool = False,
    verify: bool = False,
    bound_argnums: Sequence[int] = (),
    on_proof: Optional[Callable[[SandboxProof], None]] = None,
) -> Callable:
    """Instrument ``fn`` so every dynamic access to the arena args is fenced.

    Returns ``sandboxed(fence_params, *args) -> (outputs, ok)`` where ``ok``
    is a scalar bool: True unless the CHECK policy observed a violation
    (fencing policies always return True — they contain, not detect).

    With ``count_violations=True`` the return is ``(outputs, ok, counts)``
    where ``counts`` is a ``(NUM_KINDS,)`` int32 vector of violating
    *elements* per access class (:class:`~repro.core.violations
    .ViolationKind` order) — the per-launch row a CHECK step folds into the
    device-side ViolationLog.  Fencing policies yield all-zero counts.

    With ``verify=True`` the static bounds verifier runs over the traced
    jaxpr first: PROVEN sites get **no fence** (elided — the proof replaces
    the instruction), FENCED sites are fenced as usual, and REFUTED sites
    raise :class:`GuardianStaticViolation` at trace time.  ``bound_argnums``
    optionally names the ``(base, mask)`` argument positions the launch
    path injects the fence row into (fence-aware kernels — the paper's
    Listing-1 augmentation), which is what lets an internally-fenced kernel
    prove itself row-exact.  ``on_proof`` receives the
    :class:`~repro.core.verifier.SandboxProof` each time a new trace is
    verified (the manager uses this to cache proofs beside its jit caches).

    The returned callable is trace-time instrumented: wrap it in ``jax.jit``
    once and the fences compile into the kernel (the paper compiles the
    sandboxed PTX at manager init, §4.4).
    """
    arena_set = frozenset(arena_argnums)
    kernel_name = getattr(fn, "__name__", "<kernel>")

    @functools.wraps(fn)
    def sandboxed(fence_params: FenceParams, *args):
        # size-like python scalars stay static (CUDA-launch-dim analogue);
        # only arrays/tracers become jaxpr inputs.
        dyn_pos = [i for i, a in enumerate(args)
                   if isinstance(a, (jax.Array, np.ndarray))
                   or isinstance(a, jax.core.Tracer)]
        dyn_args = [args[p] for p in dyn_pos]

        def fn_dyn(*dargs):
            full = list(args)
            for p, v in zip(dyn_pos, dargs):
                full[p] = v
            return fn(*full)

        closed = jax.make_jaxpr(fn_dyn)(*dyn_args)
        flat_args, _ = jax.tree_util.tree_flatten(dyn_args)
        # map leaf taint: every leaf of an arena-argnum pytree is tainted
        taints, slots = _flat_taints(dyn_pos, dyn_args, arena_set)
        verdicts = None
        proof = None
        if verify:
            proof = _run_verifier(closed, taints, slots, fence_params,
                                  bound_argnums, kernel_name)
            verdicts = {s.path: s.verdict for s in proof.sites}
            if on_proof is not None:
                on_proof(proof)
        report = SandboxReport(proof=proof)
        oks = _OkAcc()
        outs, _ = _interpret(closed, flat_args, taints, fence_params,
                             policy, report, oks, verdicts)
        ok = oks.ok()
        out_tree = jax.tree_util.tree_structure(
            jax.eval_shape(fn_dyn, *dyn_args)
        )
        out = jax.tree_util.tree_unflatten(out_tree, outs)
        if not count_violations:
            return out, ok
        return out, ok, oks.counts()

    return sandboxed


def sandbox_report(
    fn: Callable,
    example_args: Sequence[Any],
    arena_argnums: Sequence[int] = (0,),
    policy: FencePolicy = FencePolicy.BITWISE,
    verify: bool = False,
    params: Optional[FenceParams] = None,
    bound_argnums: Sequence[int] = (),
) -> SandboxReport:
    """Dry-run the patcher and report how many accesses were safeguarded
    (Table 3: "#total loads / #total stores ... identified and safeguarded").

    With ``verify=True`` the report's ``proof`` field carries the static
    verifier's per-site classification (and elided sites are counted in
    ``elided_*`` instead of ``fenced_*``).  ``params=None`` verifies against
    the symbolic row."""
    example_args = tuple(example_args)
    dyn_pos = [i for i, a in enumerate(example_args)
               if isinstance(a, (jax.Array, np.ndarray))
               or isinstance(a, jax.core.Tracer)]
    dyn_args = [example_args[p] for p in dyn_pos]

    def fn_dyn(*dargs):
        full = list(example_args)
        for p, v in zip(dyn_pos, dargs):
            full[p] = v
        return fn(*full)

    closed = jax.make_jaxpr(fn_dyn)(*dyn_args)
    flat_args, _ = jax.tree_util.tree_flatten(dyn_args)
    arena_set = frozenset(arena_argnums)
    taints, slots = _flat_taints(dyn_pos, dyn_args, arena_set)
    verdicts = None
    proof = None
    if verify:
        proof = _run_verifier(closed, taints, slots, params, bound_argnums,
                              getattr(fn, "__name__", "<kernel>"))
        verdicts = {s.path: s.verdict for s in proof.sites}
    report = SandboxReport(proof=proof)
    oks = _OkAcc()
    dummy = params if (params is not None and params.is_static) \
        else FenceParams(base=0, size=1)
    _interpret(closed, flat_args, taints, dummy, policy, report, oks,
               verdicts)
    return report
