"""Jaxpr-level kernel sandboxing — the "PTX-patcher" analogue (Guardian §4.3).

The paper instruments the *virtual assembly* (PTX) of every GPU kernel —
including kernels inside closed-source libraries — inserting fence
instructions before every load/store.  The JAX analogue of "a kernel you
cannot modify at source level" is a **traced jaxpr**: third-party callables
are opaque Python, but their jaxpr is always available (the same way PTX is
always embedded for forward compatibility).

``sandbox(fn, arena_argnums)`` walks the traced jaxpr of ``fn`` and rewrites
every *data-dependent access into an arena-derived operand*:

    gather / scatter(-add/-mul/-min/-max) ........ fence the index columns
                                                    that address slot dim 0
    dynamic_slice / dynamic_update_slice ......... fence + pin the dim-0 start

Static accesses (``slice``, constant indices) are proven in-bounds by XLA at
compile time — the exact analogue of the paper treating direct branches as
safe while fencing register-addressed loads.  Indexing into *tenant-private*
tensors cannot reach the arena (separate XLA buffers, clamped OOB), matching
the paper's observation that host memory is safe via process isolation.

Taint tracking mirrors "which PTX register holds a global pointer": an
operand is fenced iff it is the arena argument or derived from it through
layout-preserving ops (convert/reshape keeping dim 0/transpose keeping dim 0
leading/copy).  Scatter outputs remain tainted (the arena flows through);
gather outputs are *values*, not slot space, so taint stops there.

Call primitives (``jit``/``pjit``, ``custom_jvp/vjp``, ``remat``,
``closed_call``) are interpreted recursively, so fences land inside library
wrappers — the paper's "implicit calls of cuBLAS" case.  ``scan/while/cond``
inside tenant kernels are rejected with a clear error: at the jaxpr level
their branch sets are static (the paper's safe direct branches), but their
carried slot-spaces would need per-iteration fencing; tenants use the
manager's guarded ops for those patterns instead (documented in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Sequence, Set, Tuple

import jax
import jax.extend.core as jex_core
import numpy as np
import jax.numpy as jnp

from repro.core.fence import FenceParams, FencePolicy, apply_fence
from repro.core.violations import NUM_KINDS, ViolationKind

# Primitives through which "this value IS the arena slot space" propagates.
_TAINT_TRANSPARENT = {
    "convert_element_type",
    "copy",
    "reshape",       # conservatively: only if dim0 preserved (checked below)
    "transpose",     # only if dim0 stays leading
    "stop_gradient",
    "reduce_precision",
}

# Scatter-family primitives: operand 0 is the arena, operand 1 the indices.
_SCATTER_PRIMS = {
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "scatter_add", "scatter_apply",
}

# Call-like primitives we interpret recursively (jaxpr param name varies).
_CALL_PRIMS = {
    "jit": "jaxpr",
    "pjit": "jaxpr",
    "closed_call": "call_jaxpr",
    "custom_jvp_call": "call_jaxpr",
    "custom_vjp_call": "call_jaxpr",
    "custom_vjp_call_jaxpr": "fun_jaxpr",
    "remat": "jaxpr",
    "checkpoint": "jaxpr",
}

_UNSUPPORTED = {"scan", "while", "cond"}


class SandboxError(Exception):
    """Raised when a tenant kernel uses a construct the sandboxer cannot
    prove safe (the manager refuses the kernel at registration time —
    fail-closed, like grdManager refusing an unknown CUDA symbol)."""


@dataclasses.dataclass
class SandboxReport:
    """What the patcher did — Table 3 analogue (#loads/#stores safeguarded)."""

    fenced_gathers: int = 0
    fenced_scatters: int = 0
    fenced_dynamic_slices: int = 0
    fenced_dynamic_updates: int = 0
    total_eqns: int = 0

    @property
    def fenced_total(self) -> int:
        return (self.fenced_gathers + self.fenced_scatters
                + self.fenced_dynamic_slices + self.fenced_dynamic_updates)


def _read(env: Dict[Any, Any], v) -> Any:
    if isinstance(v, jex_core.Literal):
        return v.val
    return env[v]


def _is_tainted(taint: Dict[Any, bool], v) -> bool:
    if isinstance(v, jex_core.Literal):
        return False
    return taint.get(v, False)


def _fence_index_columns(
    indices: jax.Array,
    cols: Sequence[int],
    params: FenceParams,
    policy: FencePolicy,
    oks: List[Tuple[ViolationKind, jax.Array]],
    kind: ViolationKind,
) -> jax.Array:
    """Fence the given trailing-dim columns of a gather/scatter index array.

    CHECK-mode ``ok`` predicates are collected *untruncated* (full element
    arrays, tagged with the access kind) so the caller can both reduce them
    to a scalar verdict and count the violating elements per kind."""
    if indices.ndim == 0:
        fenced, ok = apply_fence(policy, indices, params)
        if ok is not None:
            oks.append((kind, ok))
        return fenced.astype(indices.dtype)
    out = indices
    for c in cols:
        col = indices[..., c]
        fenced, ok = apply_fence(policy, col, params)
        if ok is not None:
            oks.append((kind, ok))
        out = out.at[..., c].set(fenced.astype(indices.dtype))
    return out


def _interpret(
    closed: Any,  # ClosedJaxpr
    args: Sequence[Any],
    tainted_in: Sequence[bool],
    params: FenceParams,
    policy: FencePolicy,
    report: SandboxReport,
    oks: List[Tuple[ViolationKind, jax.Array]],
) -> Tuple[List[Any], List[bool]]:
    jaxpr = closed.jaxpr
    env: Dict[Any, Any] = {}
    taint: Dict[Any, bool] = {}

    for var, val in zip(jaxpr.constvars, closed.consts):
        env[var] = val
        taint[var] = False
    assert len(jaxpr.invars) == len(args), (len(jaxpr.invars), len(args))
    for var, val, t in zip(jaxpr.invars, args, tainted_in):
        env[var] = val
        taint[var] = t

    for eqn in jaxpr.eqns:
        report.total_eqns += 1
        name = eqn.primitive.name
        invals = [_read(env, v) for v in eqn.invars]
        intaints = [_is_tainted(taint, v) for v in eqn.invars]

        if name in _UNSUPPORTED and any(intaints):
            raise SandboxError(
                f"tenant kernel routes the shared arena through `{name}`; "
                "use the manager's guarded ops for loop-carried arena state"
            )

        out_taint = False

        if name in _CALL_PRIMS:
            sub = eqn.params.get(_CALL_PRIMS[name])
            if sub is None:  # fall back to any ClosedJaxpr-valued param
                sub = next(v for v in eqn.params.values()
                           if hasattr(v, "jaxpr"))
            outvals, out_taints = _interpret(sub, invals, intaints, params,
                                             policy, report, oks)
            for var, val, t in zip(eqn.outvars, outvals, out_taints):
                env[var] = val
                taint[var] = t
            continue

        if name == "gather" and intaints[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in enumerate(dnums.start_index_map) if d == 0]
            if cols:
                invals = list(invals)
                invals[1] = _fence_index_columns(
                    jnp.asarray(invals[1]), cols, params, policy, oks,
                    ViolationKind.GATHER)
                report.fenced_gathers += 1
            out_taint = False  # gathered *values*, not slot space

        elif name in _SCATTER_PRIMS and intaints[0]:
            dnums = eqn.params["dimension_numbers"]
            cols = [j for j, d in
                    enumerate(dnums.scatter_dims_to_operand_dims) if d == 0]
            if cols:
                invals = list(invals)
                invals[1] = _fence_index_columns(
                    jnp.asarray(invals[1]), cols, params, policy, oks,
                    ViolationKind.SCATTER)
                report.fenced_scatters += 1
            out_taint = True  # the arena flows through a scatter

        elif name == "dynamic_slice" and intaints[0]:
            sizes = eqn.params["slice_sizes"]
            invals = list(invals)
            start0, ok = apply_fence(policy, jnp.asarray(invals[1]), params)
            if ok is not None:
                oks.append((ViolationKind.SLICE, ok))
            hi = jnp.maximum(
                jnp.asarray(params.base + params.size - sizes[0], jnp.int32),
                jnp.asarray(params.base, jnp.int32))
            invals[1] = jnp.minimum(start0, hi).astype(
                jnp.asarray(invals[1]).dtype)
            report.fenced_dynamic_slices += 1
            out_taint = False

        elif name == "dynamic_update_slice" and intaints[0]:
            invals = list(invals)
            upd_len = jnp.shape(invals[1])[0] if jnp.ndim(invals[1]) else 1
            start0, ok = apply_fence(policy, jnp.asarray(invals[2]), params)
            if ok is not None:
                oks.append((ViolationKind.UPDATE, ok))
            hi = jnp.maximum(
                jnp.asarray(params.base + params.size - upd_len, jnp.int32),
                jnp.asarray(params.base, jnp.int32))
            invals[2] = jnp.minimum(start0, hi).astype(
                jnp.asarray(invals[2]).dtype)
            report.fenced_dynamic_updates += 1
            out_taint = True

        elif name in _TAINT_TRANSPARENT and intaints[0]:
            if name == "reshape":
                old = jnp.shape(invals[0])
                new = eqn.params.get("new_sizes", None)
                out_taint = bool(old and new and old[0] == new[0])
            elif name == "transpose":
                perm = eqn.params.get("permutation", ())
                out_taint = bool(perm) and perm[0] == 0
            else:
                out_taint = True

        outvals = eqn.primitive.bind(*invals, **eqn.params)
        if not eqn.primitive.multiple_results:
            outvals = [outvals]
        for var, val in zip(eqn.outvars, outvals):
            env[var] = val
            taint[var] = out_taint

    outs = [_read(env, v) for v in jaxpr.outvars]
    out_taints = [_is_tainted(taint, v) for v in jaxpr.outvars]
    return outs, out_taints


def sandbox(
    fn: Callable,
    arena_argnums: Sequence[int] = (0,),
    policy: FencePolicy = FencePolicy.BITWISE,
    count_violations: bool = False,
) -> Callable:
    """Instrument ``fn`` so every dynamic access to the arena args is fenced.

    Returns ``sandboxed(fence_params, *args) -> (outputs, ok)`` where ``ok``
    is a scalar bool: True unless the CHECK policy observed a violation
    (fencing policies always return True — they contain, not detect).

    With ``count_violations=True`` the return is ``(outputs, ok, counts)``
    where ``counts`` is a ``(NUM_KINDS,)`` int32 vector of violating
    *elements* per access class (:class:`~repro.core.violations
    .ViolationKind` order) — the per-launch row a CHECK step folds into the
    device-side ViolationLog.  Fencing policies yield all-zero counts.

    The returned callable is trace-time instrumented: wrap it in ``jax.jit``
    once and the fences compile into the kernel (the paper compiles the
    sandboxed PTX at manager init, §4.4).
    """
    arena_set = frozenset(arena_argnums)

    @functools.wraps(fn)
    def sandboxed(fence_params: FenceParams, *args):
        # size-like python scalars stay static (CUDA-launch-dim analogue);
        # only arrays/tracers become jaxpr inputs.
        dyn_pos = [i for i, a in enumerate(args)
                   if isinstance(a, (jax.Array, np.ndarray))
                   or isinstance(a, jax.core.Tracer)]
        dyn_args = [args[p] for p in dyn_pos]

        def fn_dyn(*dargs):
            full = list(args)
            for p, v in zip(dyn_pos, dargs):
                full[p] = v
            return fn(*full)

        closed = jax.make_jaxpr(fn_dyn)(*dyn_args)
        flat_args, _ = jax.tree_util.tree_flatten(dyn_args)
        # map leaf taint: every leaf of an arena-argnum pytree is tainted
        taints: List[bool] = []
        for p, a in zip(dyn_pos, dyn_args):
            leaves = jax.tree_util.tree_leaves(a)
            taints.extend([p in arena_set] * len(leaves))
        report = SandboxReport()
        oks: List[Tuple[Any, jax.Array]] = []
        outs, _ = _interpret(closed, flat_args, taints, fence_params, policy,
                             report, oks)
        ok = jnp.all(jnp.stack([jnp.all(o) for _, o in oks])) \
            if oks else jnp.bool_(True)
        out_tree = jax.tree_util.tree_structure(
            jax.eval_shape(fn_dyn, *dyn_args)
        )
        out = jax.tree_util.tree_unflatten(out_tree, outs)
        if not count_violations:
            return out, ok
        counts = jnp.zeros((NUM_KINDS,), jnp.int32)
        for kind, o in oks:
            n_bad = jnp.sum(jnp.logical_not(o).astype(jnp.int32))
            counts = counts.at[int(kind)].add(n_bad)
        return out, ok, counts

    return sandboxed


def sandbox_report(
    fn: Callable,
    example_args: Sequence[Any],
    arena_argnums: Sequence[int] = (0,),
    policy: FencePolicy = FencePolicy.BITWISE,
) -> SandboxReport:
    """Dry-run the patcher and report how many accesses were safeguarded
    (Table 3: "#total loads / #total stores ... identified and safeguarded")."""
    example_args = tuple(example_args)
    dyn_pos = [i for i, a in enumerate(example_args)
               if isinstance(a, (jax.Array, np.ndarray))
               or isinstance(a, jax.core.Tracer)]
    dyn_args = [example_args[p] for p in dyn_pos]

    def fn_dyn(*dargs):
        full = list(example_args)
        for p, v in zip(dyn_pos, dargs):
            full[p] = v
        return fn(*full)

    closed = jax.make_jaxpr(fn_dyn)(*dyn_args)
    flat_args, _ = jax.tree_util.tree_flatten(dyn_args)
    taints: List[bool] = []
    arena_set = frozenset(arena_argnums)
    for p, a in zip(dyn_pos, dyn_args):
        leaves = jax.tree_util.tree_leaves(a)
        taints.extend([p in arena_set] * len(leaves))
    report = SandboxReport()
    oks: List[Tuple[Any, jax.Array]] = []
    dummy = FenceParams(base=0, size=1)
    _interpret(closed, flat_args, taints, dummy, policy, report, oks)
    return report
