"""Tenant quarantine & partition reclamation — the containment *policy*
layer on top of Guardian's detection machinery.

The paper's claim is that fencing lets erroneous applications keep running
without harming co-tenants; a production manager additionally needs to
*react*: a tenant whose kernels keep tripping the CHECK fence is burning
device cycles on clamped accesses and is, by definition, buggy or hostile.
This module drives the reaction as an explicit lifecycle:

    ACTIVE ──quarantine()──▶ QUARANTINED ──evict()──▶ EVICTED
       ▲                         │                       │
       └──── (= READMITTED) ◀────┴──── readmit() ────────┘

* **QUARANTINED** — the tenant's queued ops are dropped and new device
  calls are rejected (:class:`TenantQuarantined`); its partition and data
  survive, so a false positive is recoverable via :meth:`readmit`.
* **EVICTED** — the partition is scrubbed (``Arena.zero_range``), returned
  to the buddy allocator, and the tenant's compiled entries are purged from
  the per-kernel jit/symbol caches.  EVICTED is terminal: the *only* edge
  out is an explicit :meth:`readmit`, after which the tenant must register
  again to obtain a fresh partition.
* **READMITTED** — behaviourally ACTIVE (tracked separately so operators
  can see a tenant has a history); counters are wiped on re-admission.

Transition legality is enforced by :class:`QuarantineStateMachine` (pure,
host-only — also reused by the serving engine, which has no
GuardianManager).  *When* to transition is a pluggable
:class:`QuarantinePolicy`; :class:`ThresholdPolicy` quarantines after N
logged violations and optionally evicts after M.  The
:class:`QuarantineManager` polls the device-side
:class:`~repro.core.violations.ViolationLog` at drain-cycle boundaries
(never on the per-access hot path) and applies the policy.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.violations import KIND_NAMES, ViolationLog


class QuarantineError(Exception):
    """Illegal lifecycle transition (e.g. evicting an ACTIVE tenant)."""


class TenantQuarantined(QuarantineError):
    """A quarantined/evicted tenant attempted a device call."""


class TenantState(enum.Enum):
    ACTIVE = "active"
    QUARANTINED = "quarantined"
    EVICTED = "evicted"
    READMITTED = "readmitted"

    @property
    def admissible(self) -> bool:
        """May the tenant issue device calls / hold queued ops?"""
        return self in (TenantState.ACTIVE, TenantState.READMITTED)


# state -> states reachable in one legal transition
_LEGAL = {
    TenantState.ACTIVE: {TenantState.QUARANTINED},
    TenantState.READMITTED: {TenantState.QUARANTINED},
    TenantState.QUARANTINED: {TenantState.EVICTED, TenantState.READMITTED},
    # EVICTED is terminal except explicit re-admission:
    TenantState.EVICTED: {TenantState.READMITTED},
}


@dataclasses.dataclass
class TenantRecord:
    """Host-side lifecycle record (survives eviction, unlike the log row)."""

    tenant_id: str
    state: TenantState = TenantState.ACTIVE
    quarantines: int = 0
    readmissions: int = 0
    #: final per-kind counts snapshotted when the log row was recycled
    final_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    reason: str = ""
    #: automatic-readmission probe state: drain cycles spent QUARANTINED
    #: (the tenant's ops are dropped, so every cycle is clean by
    #: construction) and whether the tenant currently serves on
    #: probation — first logged violation on probation evicts
    clean_cycles: int = 0
    probation: bool = False
    #: scheduler drain-cycle stamps backing *rate-based* policies: the
    #: cycle the tenant was (re)admitted, and the cycles elapsed since —
    #: refreshed by the poll so policy objects stay pure functions of
    #: (counts, record).  A readmission resets the clock along with the
    #: wiped counters.
    admit_cycle: int = 0
    cycles_observed: int = 0


class QuarantineStateMachine:
    """Pure transition enforcement — no device or manager coupling.

    The serving engine drives one of these directly; the
    :class:`QuarantineManager` wraps one with device-side actions.
    """

    def __init__(self):
        self._records: Dict[str, TenantRecord] = {}

    # ------------------------------------------------------------------ #
    def admit(self, tenant_id: str) -> TenantRecord:
        """First registration -> ACTIVE.  Re-registering an EVICTED id
        without an explicit readmit() is the attack the state machine
        exists to stop, so it raises."""
        rec = self._records.get(tenant_id)
        if rec is None:
            rec = TenantRecord(tenant_id=tenant_id)
            self._records[tenant_id] = rec
            return rec
        if rec.state is TenantState.EVICTED:
            raise QuarantineError(
                f"tenant {tenant_id!r} is EVICTED; only an explicit "
                "readmit() may clear that state")
        return rec

    def forget(self, tenant_id: str) -> None:
        """Voluntary teardown of a healthy tenant drops the record; an
        EVICTED record is retained (the ban must survive the teardown)."""
        rec = self._records.get(tenant_id)
        if rec is not None and rec.state is not TenantState.EVICTED:
            del self._records[tenant_id]

    # ------------------------------------------------------------------ #
    def _transition(self, tenant_id: str, to: TenantState) -> TenantRecord:
        rec = self._records.get(tenant_id)
        if rec is None:
            raise QuarantineError(f"unknown tenant {tenant_id!r}")
        if to not in _LEGAL[rec.state]:
            raise QuarantineError(
                f"illegal transition {rec.state.name} -> {to.name} "
                f"for tenant {tenant_id!r}")
        rec.state = to
        return rec

    def quarantine(self, tenant_id: str, reason: str = "") -> TenantRecord:
        rec = self._transition(tenant_id, TenantState.QUARANTINED)
        rec.quarantines += 1
        rec.reason = reason
        return rec

    def evict(self, tenant_id: str, reason: str = "") -> TenantRecord:
        rec = self._transition(tenant_id, TenantState.EVICTED)
        if reason:
            rec.reason = reason
        return rec

    def readmit(self, tenant_id: str) -> TenantRecord:
        rec = self._transition(tenant_id, TenantState.READMITTED)
        rec.readmissions += 1
        rec.reason = ""
        return rec

    # ------------------------------------------------------------------ #
    def state_of(self, tenant_id: str) -> Optional[TenantState]:
        rec = self._records.get(tenant_id)
        return rec.state if rec else None

    def record_of(self, tenant_id: str) -> Optional[TenantRecord]:
        return self._records.get(tenant_id)

    def check_admission(self, tenant_id: str, api: str = "call") -> None:
        rec = self._records.get(tenant_id)
        if rec is not None and not rec.state.admissible:
            raise TenantQuarantined(
                f"{api}: tenant {tenant_id!r} is {rec.state.name}"
                + (f" ({rec.reason})" if rec.reason else ""))

    def records(self) -> List[TenantRecord]:
        return list(self._records.values())


# --------------------------------------------------------------------------- #
# Policies                                                                    #
# --------------------------------------------------------------------------- #


class QuarantinePolicy:
    """Decides transitions from a tenant's logged violation counts.

    ``counts`` is the tenant's {kind: n} dict; ``record`` its lifecycle
    record.  Subclass (or duck-type) to weight kinds, rate-limit, etc.
    """

    def should_quarantine(self, tenant_id: str, counts: Dict[str, int],
                          record: TenantRecord) -> bool:
        raise NotImplementedError

    def should_evict(self, tenant_id: str, counts: Dict[str, int],
                     record: TenantRecord) -> bool:
        return False


@dataclasses.dataclass
class ThresholdPolicy(QuarantinePolicy):
    """Quarantine past ``quarantine_after`` total violations; evict past
    ``evict_after`` (None = never auto-evict — operator decides)."""

    quarantine_after: int = 8
    evict_after: Optional[int] = None

    def should_quarantine(self, tenant_id, counts, record):
        return sum(counts.values()) >= self.quarantine_after

    def should_evict(self, tenant_id, counts, record):
        return (self.evict_after is not None
                and sum(counts.values()) >= self.evict_after)


@dataclasses.dataclass
class WeightedRatePolicy(QuarantinePolicy):
    """Threshold policy over *weighted* violation counts, with optional
    rate triggers (the richer policies the ROADMAP carried over).

    ``weights`` maps violation kinds to multipliers (unlisted kinds
    weigh 1.0) — a corrupting ``scatter`` can count 4x a stray
    ``gather``.  The absolute thresholds compare the weighted total;
    the ``*_rate`` triggers compare weighted violations *per drain
    cycle since admission* (``record.cycles_observed``, floored at
    ``min_cycles`` so one early violation can't spike the rate before
    there is a baseline).  Any trigger set to None is inert; with both
    absolute and rate triggers set, either may fire.

    This is also the policy a :class:`~repro.core.tenantclass.
    TenantClassPolicy`'s containment knobs build — per-tenant class
    policies override the manager-global policy in the quarantine poll.
    """

    quarantine_after: Optional[float] = 8
    evict_after: Optional[float] = None
    quarantine_rate: Optional[float] = None
    evict_rate: Optional[float] = None
    min_cycles: int = 4
    weights: Dict[str, float] = dataclasses.field(default_factory=dict)

    def weighted_total(self, counts: Dict[str, int]) -> float:
        return sum(n * self.weights.get(kind, 1.0)
                   for kind, n in counts.items())

    def _rate(self, weighted: float, record: TenantRecord) -> float:
        cycles = max(getattr(record, "cycles_observed", 0),
                     self.min_cycles, 1)
        return weighted / cycles

    def should_quarantine(self, tenant_id, counts, record):
        w = self.weighted_total(counts)
        if self.quarantine_after is not None and w >= self.quarantine_after:
            return True
        return (self.quarantine_rate is not None and w > 0
                and self._rate(w, record) >= self.quarantine_rate)

    def should_evict(self, tenant_id, counts, record):
        w = self.weighted_total(counts)
        if self.evict_after is not None and w >= self.evict_after:
            return True
        return (self.evict_rate is not None and w > 0
                and self._rate(w, record) >= self.evict_rate)


# --------------------------------------------------------------------------- #
# The manager-side driver                                                     #
# --------------------------------------------------------------------------- #


class QuarantineManager:
    """Polls the ViolationLog and applies a policy against a
    :class:`~repro.core.manager.GuardianManager`.

    Polling happens at drain-cycle boundaries (``maybe_poll`` from
    ``run_queued``) — the fused launch path never synchronizes.  A poll is
    skipped outright while the log is clean (no CHECK launch ran), so
    BITWISE/MODULO traffic pays nothing.
    """

    def __init__(self, manager, policy: Optional[QuarantinePolicy] = None,
                 poll_every: int = 1,
                 readmit_after: Optional[int] = None):
        if poll_every < 1:
            raise ValueError("poll_every must be >= 1")
        if readmit_after is not None and readmit_after < 1:
            raise ValueError("readmit_after must be >= 1 (or None)")
        self.manager = manager
        self.policy = policy if policy is not None else ThresholdPolicy()
        self.machine = QuarantineStateMachine()
        self.poll_every = poll_every
        #: automatic readmission probes: a QUARANTINED tenant is
        #: re-admitted after this many clean drain cycles into a
        #: *probation* partition sized by the elastic admission
        #: controller; its first logged violation on probation evicts.
        #: None (default) keeps readmission operator-only.
        self.readmit_after = readmit_after
        self._cycles_since_poll = 0
        self.events: List[str] = []   # human-readable transition trail
        # transition observers: (tenant_id, new_state) callbacks fired on
        # every quarantine/evict/readmit — the hook that propagates
        # manager-side containment into the serving plane (the engine
        # drops the tenant's pending requests and scrubs its pool slots).
        # EVICTED fires *before* partition reclamation so listeners can
        # still read the tenant's bounds.
        self._listeners: List[Callable[[str, TenantState], None]] = []

    # -- registration hooks (called by the manager) --------------------- #
    def admit(self, tenant_id: str) -> None:
        fresh = self.machine.record_of(tenant_id) is None
        rec = self.machine.admit(tenant_id)
        if fresh:
            # rate-based policies measure violations per cycle since
            # admission — stamp the clock on the record's first life
            # (a duplicate registration must not reset a live clock)
            rec.admit_cycle = self.manager.scheduler._cycle

    def forget(self, tenant_id: str) -> None:
        self.machine.forget(tenant_id)

    def check_admission(self, tenant_id: str, api: str = "call") -> None:
        self.machine.check_admission(tenant_id, api)

    def state_of(self, tenant_id: str) -> Optional[TenantState]:
        return self.machine.state_of(tenant_id)

    # -- polling --------------------------------------------------------- #
    def maybe_poll(self) -> None:
        """Cheap cadence gate for the drain loop.  ``dirty`` latches until
        poll() consumes it, so the counter only advances on dirty cycles.
        Readmission probes advance unconditionally — their clock is clean
        cycles, which are exactly the cycles the dirty gate skips."""
        self._advance_probes()
        if not self.manager.violog.dirty:
            return
        self._cycles_since_poll += 1
        if self._cycles_since_poll >= self.poll_every:
            self.poll()

    def _advance_probes(self) -> None:
        """Count QUARANTINED tenants' clean cycles (their ops are dropped,
        so every quarantined cycle is violation-free by construction) and
        probe-readmit those past ``readmit_after``."""
        if self.readmit_after is None:
            return
        for rec in self.machine.records():
            if rec.state is not TenantState.QUARANTINED:
                continue
            rec.clean_cycles += 1
            if rec.clean_cycles >= self.readmit_after:
                self.readmit_probe(rec.tenant_id)

    def readmit_probe(self, tenant_id: str) -> None:
        """Automatic probation readmission: counters wiped like a manual
        readmit, but the tenant comes back into a *probation* partition
        sized by the elastic admission controller (the smallest pow2
        extent holding its live data, floored at the policy minimum) and
        its next logged violation evicts — no second quarantine."""
        self.readmit(tenant_id)
        rec = self.machine.record_of(tenant_id)
        rec.probation = True
        rec.clean_cycles = 0
        elastic = getattr(self.manager, "elastic", None)
        if elastic is not None:
            elastic.apply_probation(tenant_id)
        self.events.append(f"probe-readmit {tenant_id} (probation)")
        self._emit(tenant_id, "probe_readmit")

    def poll(self) -> List[str]:
        """Read the log once and apply the policy.  Returns the tenant ids
        transitioned this poll (quarantined or evicted)."""
        self._cycles_since_poll = 0
        log: ViolationLog = self.manager.violog
        log.dirty = False          # only the poller consumes the flag
        snap = log.snapshot()
        tel = getattr(self.manager, "telemetry", None)
        transitioned: List[str] = []
        for tenant_id in log.tenants():
            rec = self.machine.record_of(tenant_id)
            if rec is None:
                continue
            # refresh the rate clock before the policy reads the record
            # (policies stay pure functions of (counts, record))
            rec.cycles_observed = max(
                self.manager.scheduler._cycle - rec.admit_cycle, 0)
            policy = self.policy_for(tenant_id)
            counts = log.counts(tenant_id, snap=snap)
            if tel is not None and tel.enabled:
                # piggyback on the poll's (already dirty-gated) sync: the
                # registry's violation gauges update only here, never on
                # the launch path
                for kind, n in counts.items():
                    tel.registry.set_gauge(f"violations_{kind}", n,
                                           tenant=tenant_id)
            if (rec.probation and rec.state.admissible
                    and sum(counts.values()) > 0):
                # probation (probe-readmitted) tenants get no second
                # threshold: the first logged violation evicts (via the
                # legal QUARANTINED hop — EVICTED is never entered from
                # an admissible state directly)
                self.quarantine(
                    tenant_id,
                    reason=f"probation violation ({self._fmt(counts)})")
                self.evict(tenant_id, reason="probation violation")
                transitioned.append(tenant_id)
                continue
            if rec.state.admissible and policy.should_quarantine(
                    tenant_id, counts, rec):
                self.quarantine(
                    tenant_id,
                    reason=f"{sum(counts.values())} logged violations "
                           f"({self._fmt(counts)})")
                transitioned.append(tenant_id)
                rec = self.machine.record_of(tenant_id)
            if (rec.state is TenantState.QUARANTINED
                    and policy.should_evict(tenant_id, counts, rec)):
                self.evict(tenant_id)
                transitioned.append(tenant_id)
        return transitioned

    def policy_for(self, tenant_id: str) -> QuarantinePolicy:
        """The policy governing this tenant: a registered
        :class:`~repro.core.tenantclass.TenantClassPolicy` with any
        containment knob set overrides the manager-global policy
        (containment and QoS are configured in one object)."""
        class_of = getattr(self.manager, "class_policy_of", None)
        cp = class_of(tenant_id) if class_of is not None else None
        if cp is not None:
            override = cp.quarantine_policy()
            if override is not None:
                return override
        return self.policy

    @staticmethod
    def _fmt(counts: Dict[str, int]) -> str:
        return " ".join(f"{k}={v}" for k, v in counts.items() if v)

    # -- transition observers -------------------------------------------- #
    def subscribe(self, callback: Callable[[str, TenantState], None]) -> None:
        """Register a transition observer (serving engines, operators).

        ``callback(tenant_id, new_state)`` fires after the state machine
        transitions but — for EVICTED — *before* the partition is
        reclaimed, so the listener can still resolve the tenant's bounds
        (the serve engine scrubs its pool slots with them)."""
        self._listeners.append(callback)

    def _notify(self, tenant_id: str, state: TenantState) -> None:
        for cb in self._listeners:
            cb(tenant_id, state)

    # -- transitions with device-side actions ---------------------------- #
    def quarantine(self, tenant_id: str, reason: str = "") -> None:
        """QUARANTINED: drop queued ops, reject new calls; data survives."""
        rec = self.machine.quarantine(tenant_id, reason=reason)
        rec.clean_cycles = 0            # the probe clock starts now
        self.manager._drop_tenant_ops(tenant_id)
        self.events.append(f"quarantine {tenant_id}: {reason}")
        self._emit(tenant_id, "quarantine", reason=reason)
        self._notify(tenant_id, TenantState.QUARANTINED)

    def evict(self, tenant_id: str, reason: str = "") -> None:
        """EVICTED: scrub + free the partition, purge compiled entries."""
        log: ViolationLog = self.manager.violog
        rec = self.machine.evict(tenant_id, reason=reason)
        rec.probation = False
        if log.row_of(tenant_id) is not None:
            rec.final_counts = log.counts(tenant_id)
        self._notify(tenant_id, TenantState.EVICTED)   # bounds still live
        self.manager._evict_tenant(tenant_id)
        self.events.append(f"evict {tenant_id}")
        self._emit(tenant_id, "evict", reason=reason)
        # an eviction frees slots: the elastic waitlist re-drives admission
        elastic = getattr(self.manager, "elastic", None)
        if elastic is not None:
            elastic.notify_capacity_freed()

    def readmit(self, tenant_id: str) -> None:
        """Back to service.  A QUARANTINED tenant keeps its partition; an
        EVICTED one must register again for a fresh one.  Counters reset —
        re-admission wipes the slate (an operator readmit also clears any
        probation: it is an explicit trust statement)."""
        rec = self.machine.readmit(tenant_id)
        rec.probation = False
        rec.clean_cycles = 0
        # wiped counters restart the rate-based policies' clock too
        rec.admit_cycle = self.manager.scheduler._cycle
        rec.cycles_observed = 0
        self.manager.violog.reset(tenant_id)
        self.events.append(f"readmit {tenant_id}")
        self._emit(tenant_id, "readmit")
        self._notify(tenant_id, TenantState.READMITTED)

    def _emit(self, tenant_id: str, name: str, **args) -> None:
        """Mirror a lifecycle transition into the flight recorder: a
        counter plus a trace event on the tenant's track (host dict
        writes — the poll already synchronized where needed)."""
        tel = getattr(self.manager, "telemetry", None)
        if tel is None or not tel.enabled:
            return
        tel.registry.inc(f"{name}s", tenant=tenant_id)
        tel.event(name, tenant_id,
                  **{k: v for k, v in args.items() if v})
