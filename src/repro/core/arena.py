"""Shared device arenas — the "single GPU address space" of the paper.

The grdManager reserves all device memory at startup (§4.2.1: "a custom
allocator that initially reserves all GPU memory and splits it into
partitions").  On TPU the reservation is a set of **arena tensors** living in
HBM, each an ``(num_slots, *slot_shape)`` array whose axis 0 is the shared
slot space that partitions carve up:

* the **flat arena** (slot_shape=()) models raw device DRAM for the
  client-facing malloc/memcpy/kernel API;
* structured arenas back the serving/training data paths: KV page pools,
  SSM state pools, MoE dispatch buffers, embedding tables.

Arenas are functionally updated (JAX); the manager is the only holder of the
live buffer, which is what enforces "applications do not have direct access
to the GPU" (§5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fence import (
    FenceParams,
    FencePolicy,
    guarded_dynamic_slice,
    guarded_dynamic_update_slice,
    guarded_take,
    guarded_update,
)
from repro.core.partition import is_pow2


@dataclasses.dataclass(frozen=True)
class ArenaSpec:
    """Static description of one shared arena tensor."""

    name: str
    num_slots: int                       # pow2 — the partitionable axis
    slot_shape: Tuple[int, ...] = ()
    dtype: Any = jnp.float32

    def __post_init__(self):
        if not is_pow2(self.num_slots):
            raise ValueError(
                f"arena {self.name!r}: num_slots {self.num_slots} not pow2")

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.num_slots, *self.slot_shape)

    def abstract(self) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def allocate(self) -> jax.Array:
        return jnp.zeros(self.shape, self.dtype)

    @property
    def slot_bytes(self) -> int:
        import numpy as np
        n = 1
        for d in self.slot_shape:
            n *= d
        return n * np.dtype(self.dtype).itemsize

    @property
    def total_bytes(self) -> int:
        return self.num_slots * max(self.slot_bytes, 1)


class PoolArena:
    """A live *pytree* arena — framework-plane pools (KV page pools, SSM
    state pools) registered with the manager so the manager, not the
    serving engine, holds the only reference to the device buffers.

    Unlike :class:`Arena` there is no flat spec: the buffer is an
    arbitrary pytree whose slot-indexed tensors share the manager's
    global slot space on axis 1.  Trusted kernels declaring
    ``pool_arena=<name>`` have the pool threaded through their compiled
    steps (and through every row of a fused multi-engine step) exactly
    like the flat arena — one live pool, engines only ever see the value
    the manager hands their step.
    """

    def __init__(self, buf: Any):
        self.buf = buf
        #: Optional :class:`PagePool` when the pool is the global paged KV
        #: layout (virtual page extents + manager-owned page_map).
        self.pages: Optional["PagePool"] = None


class PagePool:
    """Virtual->physical page allocator for the global paged KV pool.

    Tenant partitions on the manager's buddy allocator are *virtual* page
    extents; device-side page tables hold virtual ids that are fenced into
    the tenant's extent and then translated through :attr:`page_map` (the
    operand behind ``GuardSpec.page_map``).  Physical pages are handed out
    FIFO from a free list, so elastic compaction / resize is a host-side
    rewrite of the map — zero relocation copy steps on device.

    Invariant: every virtual id inside a bound extent maps to a physical
    page owned by exactly one extent; released physical pages return to
    the free list only after their map entries are retargeted to 0 (page
    0 stays allocator-owned as the scratch/garbage page every unbound
    virtual id resolves to).
    """

    def __init__(self, total_pages: int, virt_pages: int):
        import numpy as np
        if total_pages < 1:
            raise ValueError("PagePool needs at least 1 physical page")
        self.total_pages = total_pages
        self.page_map = np.zeros((virt_pages,), np.int32)
        # phys page 0 is the sink for unbound virtual ids — never handed out
        self._free = list(range(1, total_pages))
        self._extents: Dict[str, Tuple[int, int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.total_pages - 1 - len(self._free)

    def occupancy(self) -> float:
        denom = max(self.total_pages - 1, 1)
        return self.used_pages / denom

    def extent_of(self, tenant: str) -> Optional[Tuple[int, int]]:
        return self._extents.get(tenant)

    def bind_extent(self, tenant: str, base: int, size: int) -> None:
        """Back virtual pages [base, base+size) with physical pages.

        Called when a tenant partition is created or grown; idempotent per
        (tenant, extent) — a grow rebinds only the newly added tail."""
        old = self._extents.get(tenant)
        lo, hi = base, base + size
        if old is not None:
            if old[0] != base:
                raise ValueError(
                    f"bind_extent({tenant}): base moved {old[0]}->{base}; "
                    "use rebase_extent")
            if size < old[1]:
                raise ValueError(
                    f"bind_extent({tenant}): shrink {old[1]}->{size}; "
                    "use shrink_extent")
            lo = base + old[1]                 # extend the tail only
        need = hi - lo
        if need > len(self._free):
            raise MemoryError(
                f"page pool exhausted: need {need}, free {len(self._free)}")
        for v in range(lo, hi):
            self.page_map[v] = self._free.pop(0)
        self._extents[tenant] = (base, size)

    def shrink_extent(self, tenant: str, new_size: int) -> None:
        base, size = self._extents[tenant]
        for v in range(base + new_size, base + size):
            self._free.append(int(self.page_map[v]))
            self.page_map[v] = 0
        self._extents[tenant] = (base, new_size)

    def release_extent(self, tenant: str) -> None:
        base, size = self._extents.pop(tenant, (0, 0))
        for v in range(base, base + size):
            phys = int(self.page_map[v])
            if phys:
                self._free.append(phys)
            self.page_map[v] = 0

    def rebase_extent(self, tenant: str, new_base: int) -> None:
        """Move a tenant's *virtual* extent — the zero-copy compaction
        primitive.  Physical pages keep their bytes; only map rows move."""
        base, size = self._extents[tenant]
        if new_base == base:
            return
        phys = [int(self.page_map[v]) for v in range(base, base + size)]
        for v in range(base, base + size):
            self.page_map[v] = 0
        for i, p in enumerate(phys):
            self.page_map[new_base + i] = p
        self._extents[tenant] = (new_base, size)

    def owner_of_phys(self, phys: int) -> Optional[str]:
        """Debug/audit: which tenant extent maps to a physical page."""
        for t, (base, size) in self._extents.items():
            for v in range(base, base + size):
                if int(self.page_map[v]) == phys:
                    return t
        return None


class Arena:
    """A live arena: spec + current buffer.  All dynamic access goes through
    the guarded ops so the fence policy is applied uniformly."""

    def __init__(self, spec: ArenaSpec, buf: Optional[jax.Array] = None):
        self.spec = spec
        self.buf = spec.allocate() if buf is None else buf

    # -- fenced row access ------------------------------------------------
    def read_rows(self, idx, params: FenceParams,
                  policy: FencePolicy = FencePolicy.BITWISE) -> jax.Array:
        return guarded_take(self.buf, idx, params, policy)

    def write_rows(self, idx, values, params: FenceParams,
                   policy: FencePolicy = FencePolicy.BITWISE) -> None:
        self.buf = guarded_update(self.buf, idx, values, params, policy)

    def read_range(self, start, length: int, params: FenceParams,
                   policy: FencePolicy = FencePolicy.BITWISE) -> jax.Array:
        return guarded_dynamic_slice(self.buf, start, length, params, policy)

    def write_range(self, start, values, params: FenceParams,
                    policy: FencePolicy = FencePolicy.BITWISE) -> None:
        self.buf = guarded_dynamic_update_slice(
            self.buf, start, values, params, policy)

    # -- unfenced (manager-internal, pre-validated) -----------------------
    def unsafe_read_range(self, start: int, length: int) -> jax.Array:
        return jax.lax.dynamic_slice_in_dim(self.buf, start, length, axis=0)

    def unsafe_write_range(self, start: int, values: jax.Array) -> None:
        self.buf = jax.lax.dynamic_update_slice_in_dim(
            self.buf, values, start, axis=0)

    def zero_range(self, start: int, length: int) -> None:
        """Scrub a partition on tenant teardown (no cross-tenant leaks)."""
        z = jnp.zeros((length, *self.spec.slot_shape), self.spec.dtype)
        self.unsafe_write_range(start, z)

    @property
    def nbytes(self) -> int:
        return self.spec.total_bytes


def make_kv_page_arena(num_pages: int, page_size: int, num_kv_heads: int,
                       head_dim: int, dtype=jnp.bfloat16,
                       name: str = "kv_pages") -> ArenaSpec:
    """Paged-KV pool: slot = one page of K and V (stacked on a leading 2)."""
    return ArenaSpec(name=name, num_slots=num_pages,
                     slot_shape=(2, page_size, num_kv_heads, head_dim),
                     dtype=dtype)


def make_state_arena(num_cells: int, state_dim: int, head_dim: int,
                     dtype=jnp.float32, name: str = "ssm_state") -> ArenaSpec:
    """SSM/recurrent state pool (zamba2 Mamba2 layers, xLSTM cells)."""
    return ArenaSpec(name=name, num_slots=num_cells,
                     slot_shape=(state_dim, head_dim), dtype=dtype)


def make_flat_arena(num_slots: int, dtype=jnp.float32,
                    name: str = "device_dram") -> ArenaSpec:
    """The raw device-DRAM model used by the client malloc/memcpy API."""
    return ArenaSpec(name=name, num_slots=num_slots, slot_shape=(),
                     dtype=dtype)
