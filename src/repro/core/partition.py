"""Power-of-two arena partitioning — Guardian §4.2.1.

Guardian reserves all device memory at startup and carves it into *contiguous,
power-of-two sized, size-aligned* partitions, one per tenant.  Contiguity +
pow2 alignment is what makes the bounds metadata two scalars (``base``,
``mask = size - 1``) and the fence two bitwise instructions (§4.4).

On TPU the arena is a *slot space*: slot 0..N-1 of a shared HBM tensor
(KV pages, embedding rows, SSM state cells, MoE buffer rows).  A partition is
a contiguous slot range.  The buddy allocator below maintains the paper's two
invariants:

  I1  size is a power of two,
  I2  base is aligned to size  (``base % size == 0``),

which together guarantee ``(x & mask) | base`` maps *any* integer into
``[base, base + size)`` and is the identity on in-partition values.  These
invariants are property-tested in ``tests/test_partition.py``.
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n <= 0:
        raise ValueError(f"size must be positive, got {n}")
    return 1 << (n - 1).bit_length()


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class Partition:
    """One tenant's contiguous slot range.  ``mask == size - 1``."""

    tenant_id: str
    base: int
    size: int

    def __post_init__(self):
        if not is_pow2(self.size):
            raise ValueError(f"partition size {self.size} not a power of two")
        if self.base % self.size != 0:
            raise ValueError(
                f"partition base {self.base} not aligned to size {self.size}"
            )

    @property
    def mask(self) -> int:
        return self.size - 1

    @property
    def end(self) -> int:
        return self.base + self.size

    def contains(self, lo: int, hi: Optional[int] = None) -> bool:
        """Range check used for host-initiated transfers (§4.2.2)."""
        hi = lo + 1 if hi is None else hi
        return self.base <= lo and hi <= self.end

    def bounds_row(self) -> Tuple[int, int, int]:
        """(base, mask, size) — the row passed to kernels as scalar operands.

        The paper stores these in two registers; we pass them as SMEM scalars.
        """
        return (self.base, self.mask, self.size)


class OutOfArenaMemory(Exception):
    pass


class UnknownTenant(KeyError):
    pass


class BuddyAllocator:
    """Classic buddy allocator over ``total`` slots (``total`` pow2).

    Free lists per order; split on alloc, coalesce buddies on free.  All
    blocks it hands out satisfy I1/I2 by construction.
    """

    def __init__(self, total: int):
        if not is_pow2(total):
            raise ValueError(f"arena total {total} must be a power of two")
        self.total = total
        self._max_order = total.bit_length() - 1
        # order -> sorted list of free block bases
        self._free: Dict[int, List[int]] = {o: [] for o in range(self._max_order + 1)}
        self._free[self._max_order] = [0]
        self._allocated: Dict[int, int] = {}  # base -> order

    def _order_for(self, size: int) -> int:
        return next_pow2(size).bit_length() - 1

    def alloc(self, size: int) -> Tuple[int, int]:
        """Returns (base, rounded_size).  Raises OutOfArenaMemory."""
        order = self._order_for(size)
        if order > self._max_order:
            raise OutOfArenaMemory(
                f"request {size} exceeds arena total {self.total}"
            )
        # Find the smallest order >= `order` with a free block.
        o = order
        while o <= self._max_order and not self._free[o]:
            o += 1
        if o > self._max_order:
            raise OutOfArenaMemory(
                f"no free block of {1 << order} slots (arena fragmented/full)"
            )
        base = self._free[o].pop(0)
        # Split down to the requested order.  The split buddy is above every
        # block already free at that order's prefix we could have split from,
        # but not necessarily the list tail — insort keeps the order without
        # the O(n log n) re-sort (hot on tenant churn: every quarantine
        # eviction frees and re-splits partitions).
        while o > order:
            o -= 1
            buddy = base + (1 << o)
            bisect.insort(self._free[o], buddy)
        self._allocated[base] = order
        return base, 1 << order

    def free(self, base: int) -> None:
        if base not in self._allocated:
            raise KeyError(f"free of unallocated base {base}")
        order = self._allocated.pop(base)
        # Coalesce with buddy while possible (binary search per level —
        # the free lists are maintained sorted).
        while order < self._max_order:
            buddy = base ^ (1 << order)
            lst = self._free[order]
            i = bisect.bisect_left(lst, buddy)
            if i < len(lst) and lst[i] == buddy:
                lst.pop(i)
                base = min(base, buddy)
                order += 1
            else:
                break
        bisect.insort(self._free[order], base)

    # -- elastic resizing (core/elastic.py) ------------------------------ #
    def grow_in_place(self, base: int) -> Optional[int]:
        """Double an allocated block in place when its right-hand buddy is
        free: ``[base, base+size)`` becomes ``[base, base+2*size)``.

        Returns the new size, or None when the block cannot grow without
        moving (base not aligned to 2*size — the buddy lies *below* and
        absorbing it would change the base — or the buddy is occupied).
        The caller relocates instead (:mod:`repro.core.elastic`).
        """
        if base not in self._allocated:
            raise KeyError(f"grow of unallocated base {base}")
        order = self._allocated[base]
        if order >= self._max_order:
            return None
        if base % (1 << (order + 1)) != 0:
            return None                      # buddy is below: base would move
        buddy = base + (1 << order)
        lst = self._free[order]
        i = bisect.bisect_left(lst, buddy)
        if i >= len(lst) or lst[i] != buddy:
            return None                      # buddy occupied or split
        lst.pop(i)
        self._allocated[base] = order + 1
        return 1 << (order + 1)

    def shrink_in_place(self, base: int, new_size: int) -> int:
        """Shrink an allocated block to ``new_size`` (pow2, <= current),
        keeping its base and freeing the vacated upper buddies.  Returns
        the new size.  Invariants I1/I2 hold by construction: ``base`` was
        aligned to the old (larger) size, hence to every smaller one."""
        if base not in self._allocated:
            raise KeyError(f"shrink of unallocated base {base}")
        if not is_pow2(new_size):
            raise ValueError(f"shrink target {new_size} not a power of two")
        order = self._allocated[base]
        new_order = new_size.bit_length() - 1
        if new_order > order:
            raise ValueError(
                f"shrink target {new_size} exceeds block size {1 << order}")
        while order > new_order:
            order -= 1
            # free the upper half at each level (coalesce-safe: its buddy
            # — the kept lower half — stays allocated)
            bisect.insort(self._free[order], base + (1 << order))
        self._allocated[base] = new_order
        return new_size

    def free_slots(self) -> int:
        return sum(len(v) << o for o, v in self._free.items())

    def largest_free_block(self) -> int:
        """Size of the largest currently-free block — what the *next*
        allocation can get without anyone moving (the admission
        controller's fragmentation probe)."""
        for o in range(self._max_order, -1, -1):
            if self._free[o]:
                return 1 << o
        return 0

    def peek_alloc(self, size: int) -> Optional[int]:
        """The base :meth:`alloc` *would* return for ``size``, without
        allocating: the lowest free base at the smallest adequate order
        (splitting keeps the popped base).  None when no block fits —
        the compaction planner's read-only placement probe."""
        order = self._order_for(size)
        if order > self._max_order:
            return None
        for o in range(order, self._max_order + 1):
            if self._free[o]:
                return self._free[o][0]
        return None


class PartitionBoundsTable:
    """Guardian's *partition bounds table* (§4.2.1).

    Maps tenant -> Partition and exports the dense (base, mask, size) arrays
    that kernels consume as scalar operands.  Thread-safe: the manager mutates
    it from the control thread while launch paths read it.
    """

    def __init__(self, total_slots: int):
        self.total_slots = total_slots
        self._alloc = BuddyAllocator(total_slots)
        self._parts: Dict[str, Partition] = {}
        self._lock = threading.Lock()

    def create(self, tenant_id: str, requested_slots: int) -> Partition:
        with self._lock:
            if tenant_id in self._parts:
                raise ValueError(f"tenant {tenant_id!r} already has a partition")
            base, size = self._alloc.alloc(requested_slots)
            part = Partition(tenant_id=tenant_id, base=base, size=size)
            self._parts[tenant_id] = part
            return part

    def destroy(self, tenant_id: str) -> None:
        with self._lock:
            part = self._parts.pop(tenant_id, None)
            if part is None:
                raise UnknownTenant(tenant_id)
            self._alloc.free(part.base)

    def lookup(self, tenant_id: str) -> Partition:
        try:
            return self._parts[tenant_id]
        except KeyError:
            raise UnknownTenant(tenant_id) from None

    def tenants(self) -> List[str]:
        return list(self._parts)

    def __len__(self) -> int:
        return len(self._parts)

    def free_slots(self) -> int:
        return self._alloc.free_slots()

    def largest_free_block(self) -> int:
        return self._alloc.largest_free_block()

    # -- elastic resizing (core/elastic.py) ------------------------------ #
    def grow(self, tenant_id: str) -> Optional[Partition]:
        """Double a tenant's partition in place (buddy absorb).  Returns
        the new Partition, or None when in-place growth is impossible
        (the elastic manager relocates instead)."""
        with self._lock:
            part = self.lookup(tenant_id)
            new_size = self._alloc.grow_in_place(part.base)
            if new_size is None:
                return None
            new = Partition(tenant_id=tenant_id, base=part.base,
                            size=new_size)
            self._parts[tenant_id] = new
            return new

    def shrink(self, tenant_id: str, new_slots: int) -> Partition:
        """Shrink a tenant's partition in place to ``next_pow2(new_slots)``
        slots, keeping its base.  The caller guarantees the tenant's live
        data already fits below the new boundary (repacked first)."""
        with self._lock:
            part = self.lookup(tenant_id)
            size = next_pow2(max(new_slots, 1))
            if size >= part.size:
                return part
            self._alloc.shrink_in_place(part.base, size)
            new = Partition(tenant_id=tenant_id, base=part.base, size=size)
            self._parts[tenant_id] = new
            return new

    def relocate(self, tenant_id: str, new_slots: int
                 ) -> Tuple[Partition, Partition]:
        """Move a tenant to a freshly-allocated extent of
        ``next_pow2(new_slots)`` slots.  Both extents are allocated while
        this returns — the caller copies device data old -> new, then
        commits with :meth:`release_old` (or rolls back by freeing the
        new base).  Returns ``(old, new)``."""
        with self._lock:
            old = self.lookup(tenant_id)
            base, size = self._alloc.alloc(new_slots)
            new = Partition(tenant_id=tenant_id, base=base, size=size)
            self._parts[tenant_id] = new
            return old, new

    def release_old(self, old: Partition) -> None:
        """Return a relocated-away extent to the allocator (the device
        copy landed; the old slots were scrubbed)."""
        with self._lock:
            self._alloc.free(old.base)

    def bounds_arrays(self) -> Dict[str, np.ndarray]:
        """Dense arrays (one row per tenant, sorted by id) — for batched
        multi-tenant kernels that fence per-row with a tenant-id lookup."""
        ids = sorted(self._parts)
        base = np.array([self._parts[t].base for t in ids], dtype=np.int32)
        mask = np.array([self._parts[t].mask for t in ids], dtype=np.int32)
        size = np.array([self._parts[t].size for t in ids], dtype=np.int32)
        return {"tenant_ids": ids, "base": base, "mask": mask, "size": size}


class IntraPartitionAllocator:
    """First-fit free-list allocator *within* one partition.

    Serves a tenant's malloc()/free() calls from its own partition
    (§4.2.1: "allocation calls of each application are served from its
    partition").  No cross-tenant metadata — everything here is in
    partition-relative slot units.
    """

    def __init__(self, part: Partition):
        self.part = part
        self._free: List[Tuple[int, int]] = [(0, part.size)]  # (rel_base, len)
        self._live: Dict[int, int] = {}  # rel_base -> len

    def alloc(self, n: int) -> int:
        if n <= 0:
            raise ValueError("alloc size must be positive")
        for i, (b, ln) in enumerate(self._free):
            if ln >= n:
                if ln == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (b + n, ln - n)
                self._live[b] = n
                return b
        raise OutOfArenaMemory(
            f"tenant {self.part.tenant_id!r}: no {n} contiguous free slots"
        )

    def free(self, rel_base: int) -> None:
        n = self._live.pop(rel_base, None)
        if n is None:
            raise KeyError(f"free of unallocated offset {rel_base}")
        bisect.insort(self._free, (rel_base, n))
        # coalesce
        merged: List[Tuple[int, int]] = []
        for b, ln in self._free:
            if merged and merged[-1][0] + merged[-1][1] == b:
                merged[-1] = (merged[-1][0], merged[-1][1] + ln)
            else:
                merged.append((b, ln))
        self._free = merged

    def live_bytes(self) -> int:
        return sum(self._live.values())

    def live_span(self) -> int:
        """One past the highest live slot (0 when nothing is live) — the
        minimum in-place partition size that loses no data."""
        return max((b + n for b, n in self._live.items()), default=0)

    def repack_plan(self) -> List[Tuple[int, int, int]]:
        """Compaction plan: ``(old_rel, new_rel, len)`` moves that pack
        every live allocation to the front of the partition, in ascending
        offset order.  Ascending order with ``new <= old`` per move makes
        the sequential device copy overlap-safe (a later move's source is
        never clobbered by an earlier move's destination).  No state is
        mutated — apply with :meth:`commit_repack`."""
        moves: List[Tuple[int, int, int]] = []
        cursor = 0
        for b in sorted(self._live):
            n = self._live[b]
            if b != cursor:
                moves.append((b, cursor, n))
            cursor += n
        return moves

    def commit_repack(self, part: Partition,
                      moves: List[Tuple[int, int, int]]) -> None:
        """Apply a repack plan (device copy already landed) and rebase the
        allocator onto ``part`` — the tenant's (possibly resized /
        relocated) partition.  Live offsets shift per the plan; the free
        list becomes one tail extent."""
        remap = {old: new for old, new, _ in moves}
        self._live = {remap.get(b, b): n for b, n in self._live.items()}
        self.part = part
        used = sum(self._live.values())
        if used > part.size:
            raise OutOfArenaMemory(
                f"tenant {part.tenant_id!r}: {used} live slots exceed "
                f"resized partition ({part.size})")
        self._free = [(used, part.size - used)] if used < part.size else []

    def rebase(self, part: Partition) -> None:
        """Adopt a resized partition without moving live data (in-place
        grow/shrink, or a relocation that preserved relative offsets).
        Free space is recomputed against the new size."""
        if self.live_span() > part.size:
            raise OutOfArenaMemory(
                f"tenant {part.tenant_id!r}: live span {self.live_span()} "
                f"exceeds resized partition ({part.size})")
        old_size = self.part.size
        self.part = part
        if part.size > old_size:
            self._free.append((old_size, part.size - old_size))
        else:
            self._free = [(b, min(ln, part.size - b))
                          for b, ln in self._free if b < part.size]
        # coalesce (mirrors free())
        self._free.sort()
        merged: List[Tuple[int, int]] = []
        for b, ln in self._free:
            if merged and merged[-1][0] + merged[-1][1] == b:
                merged[-1] = (merged[-1][0], merged[-1][1] + ln)
            else:
                merged.append((b, ln))
        self._free = merged
