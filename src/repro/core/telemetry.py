"""Guardian flight recorder — unified per-tenant telemetry for the
manager plane (the operability substrate the SLO-aware scheduling
roadmap item builds on).

Guardian's runtime state used to be spread over five disconnected ad-hoc
surfaces (``LaunchStats.summary()``, ``SchedulerStats.summary()``,
``violation_report()``, ``jit_cache_stats()``, the elastic manager's
counters) with no timeline, no percentiles, and no export format.  This
module unifies them behind two host-side primitives:

* :class:`MetricsRegistry` — per-tenant counters, gauges, and
  fixed-bucket :class:`Histogram`\\ s (queue age, fused-step width,
  drain-cycle wall time, arena utilization, violation counts by kind,
  jit-cache occupancy, waitlist age, compaction slots moved).  Every
  record path is a dict write over values the host already owns; p50/p90/
  p99 are derived from the buckets host-side on demand.
* :class:`EventTrace` — a bounded ring buffer of structured lifecycle
  events (admission, resize, compaction, quarantine transitions,
  lookahead hold/flush, fence elision via proven steps, drain cycles)
  stamped with the scheduler's monotonic drain-cycle counter plus a wall
  clock, exportable as Chrome/Perfetto ``trace_event`` JSON (one track
  per tenant, one per scheduler, one per completed serve request) for
  ``ui.perfetto.dev``.  Ring overflow is counted (:attr:`EventTrace.
  dropped`) and surfaced in ``metrics_report()`` / ``repro.top``.
* :class:`RequestSpan` / :class:`SpanLedger` — request-level tracing for
  the serving plane.  Every serve request owns a span whose lifetime is
  partitioned into phases (``queue``/``hold``/``prefill``/``decode``/
  ``preempt``/``stall``) on the **drain-cycle clock**: the serving
  drivers mark phase transitions at existing drain-cycle boundaries, so
  the per-phase component cycles always sum *exactly* to the end-to-end
  latency (asserted in tests/test_spans.py and the production
  macro-bench).  Closing a span feeds the per-tenant-class SLO
  attainment ledger (attained/violated + violation-cause histogram — a
  latency-critical span violates when its *slack* cycles, the
  queue+hold+preempt+stall sum, exceed the class's ``queue_age_budget``)
  and emits per-request Perfetto tracks linked to the submit instant by
  flow events.

**Sync-freedom invariant** (the ViolationLog discipline): nothing here
ever reads device memory.  Counters and histograms are fed from host
state at the existing drain-cycle boundaries — the violation gauges, for
example, update only inside the QuarantineManager's dirty-flag-gated
poll, which was already synchronizing.  BITWISE/MODULO hot-path traffic
therefore pays a handful of dict writes when telemetry is on and a
single ``enabled`` check when it is off (``GuardianManager(telemetry=
False)`` — asserted byte-identical and sync-identical in
tests/test_telemetry.py, and ≤5% fused-drain cost by the
``telemetry.overhead`` bench row).

The :class:`Telemetry` facade owns both primitives plus the unified
report assembly: ``manager.metrics_report()`` delegates here, and the
legacy ``violation_report()`` / ``jit_cache_stats()`` surfaces are thin
views (:meth:`Telemetry.violation_view`, :meth:`Telemetry.jit_cache_view`)
kept API-compatible.  :meth:`MetricsRegistry.to_prometheus` writes the
text exposition format; ``python -m repro.top`` renders the terminal
dashboard (:mod:`repro.launch.dashboard`).
"""

from __future__ import annotations

import bisect
import json
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional, \
    Tuple

__all__ = [
    "Histogram",
    "MetricsRegistry",
    "TraceEvent",
    "EventTrace",
    "RequestSpan",
    "SpanLedger",
    "Telemetry",
    "QUEUE_AGE_BOUNDS",
    "WIDTH_BOUNDS",
    "WALL_US_BOUNDS",
    "SLOTS_BOUNDS",
    "E2E_CYCLE_BOUNDS",
    "SPAN_PHASES",
    "SLACK_PHASES",
]

#: global (non-tenant) series key inside the registry maps — a plain
#: string so snapshots stay JSON-serializable
GLOBAL = ""

#: drain-cycle ages (queue age, waitlist age): small ints, pow2 buckets
QUEUE_AGE_BOUNDS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
#: fused-step widths: max_fuse rarely exceeds 16
WIDTH_BOUNDS: Tuple[float, ...] = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)
#: wall-clock microseconds (drain cycles): 1us .. ~67s, geometric x4
WALL_US_BOUNDS: Tuple[float, ...] = tuple(float(4 ** i) for i in range(14))
#: slot counts (compaction moves, partition sizes): pow4 up to 2^30
SLOTS_BOUNDS: Tuple[float, ...] = tuple(float(4 ** i) for i in range(16))
#: end-to-end request latencies in drain cycles: pow2 up to 4096
E2E_CYCLE_BOUNDS: Tuple[float, ...] = \
    (0.0,) + tuple(float(1 << i) for i in range(13))


class Histogram:
    """Fixed-bucket host-side histogram with percentile extraction.

    ``bounds`` are ascending inclusive bucket upper edges; one implicit
    overflow bucket catches everything above the last edge.  Observation
    is a bisect + two adds — no allocation, no device work — and the
    state is plain ints, so two runs observing the same sequence are
    bit-identical (the telemetry determinism tests rely on this).
    Percentiles report the *upper edge* of the bucket holding the
    requested rank (the exact max for the overflow bucket), the standard
    fixed-bucket estimate: exact for integer series whose values are
    edges (queue ages, widths), conservative otherwise.
    """

    __slots__ = ("bounds", "buckets", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Iterable[float] = QUEUE_AGE_BOUNDS):
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        if not self.bounds:
            raise ValueError("Histogram needs at least one bucket edge")
        if any(a >= b for a, b in zip(self.bounds, self.bounds[1:])):
            raise ValueError(
                f"bucket edges must be strictly ascending: {self.bounds}")
        self.buckets: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        self.buckets[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        # inline comparisons, not min()/max(): this is the per-launch
        # hot path of the fused drain (telemetry.overhead bench row)
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper bucket edge holding rank ``ceil(q/100 * count)``; 0.0
        when empty; the exact observed max for the overflow bucket."""
        if self.count == 0:
            return 0.0
        rank = max(1, -(-int(q * self.count) // 100))   # ceil without float
        acc = 0
        for i, c in enumerate(self.buckets):
            acc += c
            if acc >= rank:
                if i < len(self.bounds):
                    return self.bounds[i]
                return float(self.vmax)
        return float(self.vmax)           # pragma: no cover (acc==count)

    def percentiles(self, qs: Tuple[int, ...] = (50, 90, 99)
                    ) -> Dict[str, float]:
        out = {f"p{q}": self.percentile(q) for q in qs}
        out["count"] = float(self.count)
        out["mean"] = self.mean
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "bounds": list(self.bounds),
            "buckets": list(self.buckets),
            "count": self.count,
            "total": self.total,
            "min": self.vmin,
            "max": self.vmax,
            **{k: v for k, v in self.percentiles().items()
               if k not in ("count",)},
        }


class MetricsRegistry:
    """Per-tenant counters, gauges, and histograms, keyed ``(name,
    tenant)`` with ``tenant=None`` for manager-global series.

    ``enabled=False`` turns every mutator into a single-branch no-op —
    the ``telemetry=off`` knob — while reads keep working (they report
    empty).  Histograms observed under a name registered in
    ``timing=True`` mode (wall-clock series) are excluded from
    ``snapshot(include_timing=False)``, which is the comparison surface
    of the determinism tests: logical metrics must be bit-identical
    across jit/eager runs, wall clocks cannot be.
    """

    #: default bucket edges per histogram name; unknown names fall back
    #: to QUEUE_AGE_BOUNDS unless ``bounds=`` is passed at first observe
    HISTOGRAM_BOUNDS: Dict[str, Tuple[float, ...]] = {
        "queue_age_cycles": QUEUE_AGE_BOUNDS,
        "waitlist_age_cycles": QUEUE_AGE_BOUNDS,
        "fused_step_width": WIDTH_BOUNDS,
        "drain_cycle_us": WALL_US_BOUNDS,
        "compaction_slots_moved": SLOTS_BOUNDS,
        "request_e2e_cycles": E2E_CYCLE_BOUNDS,
        "request_e2e_us": WALL_US_BOUNDS,
    }

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.counters: Dict[str, Dict[str, int]] = {}
        self.gauges: Dict[str, Dict[str, float]] = {}
        self.histograms: Dict[str, Dict[str, Histogram]] = {}
        self._timing_names: set = set()
        #: ``(name, tenant) -> Histogram`` shadow of ``histograms`` — the
        #: per-launch observe fast path pays one flat dict hit instead of
        #: two nested ones (telemetry.overhead bench row)
        self._flat_hists: Dict[Tuple[str, str], Histogram] = {}
        #: bumped by :meth:`forget_tenant`; holders of :meth:`hist`
        #: handles re-resolve when it changes
        self.epoch = 0

    # -- mutators (hot-ish paths: dict writes only; ``get``-then-create
    # rather than ``setdefault(name, {})``, which would allocate a
    # throwaway dict per call on the per-launch drain path) ------------- #
    def inc(self, name: str, n: int = 1,
            tenant: Optional[str] = None) -> None:
        if not self.enabled:
            return
        series = self.counters.get(name)
        if series is None:
            series = self.counters[name] = {}
        key = tenant if tenant is not None else GLOBAL
        series[key] = series.get(key, 0) + n

    def set_gauge(self, name: str, value: float,
                  tenant: Optional[str] = None) -> None:
        if not self.enabled:
            return
        series = self.gauges.get(name)
        if series is None:
            series = self.gauges[name] = {}
        series[tenant if tenant is not None else GLOBAL] = float(value)

    def observe(self, name: str, value: float,
                tenant: Optional[str] = None,
                bounds: Optional[Iterable[float]] = None,
                timing: bool = False) -> None:
        if not self.enabled:
            return
        key = tenant if tenant is not None else GLOBAL
        hist = self._flat_hists.get((name, key))
        if hist is None:
            # first observe of a series: ``timing`` and ``bounds``
            # register there, so they are first-call attributes (every
            # call site passes them constantly anyway)
            hist = self.hist(name, tenant, bounds=bounds, timing=timing)
        hist.observe(value)

    def forget_tenant(self, tenant_id: str) -> None:
        """Drop a departed tenant's series (lifetime counters of evicted
        tenants survive in the quarantine records, not here)."""
        for table in (self.counters, self.gauges, self.histograms):
            for series in table.values():
                series.pop(tenant_id, None)
        for key in [k for k in self._flat_hists if k[1] == tenant_id]:
            del self._flat_hists[key]
        self.epoch += 1

    def hist(self, name: str, tenant: Optional[str] = None,
             bounds: Optional[Iterable[float]] = None,
             timing: bool = False) -> Optional[Histogram]:
        """Live :class:`Histogram` handle for a series (created empty on
        first request), or None when disabled — the per-launch hot paths
        observe through a cached handle instead of paying the registry
        lookup per sample.  Handles die with :meth:`forget_tenant`:
        cache them no longer than :attr:`epoch` stays unchanged."""
        if not self.enabled:
            return None
        key = tenant if tenant is not None else GLOBAL
        hist = self._flat_hists.get((name, key))
        if hist is None:
            if timing:
                self._timing_names.add(name)
            series = self.histograms.get(name)
            if series is None:
                series = self.histograms[name] = {}
            hist = series[key] = Histogram(
                bounds if bounds is not None
                else self.HISTOGRAM_BOUNDS.get(name, QUEUE_AGE_BOUNDS))
            self._flat_hists[(name, key)] = hist
        return hist

    # -- reads ---------------------------------------------------------- #
    def counter(self, name: str, tenant: Optional[str] = None) -> int:
        return self.counters.get(name, {}).get(
            tenant if tenant is not None else GLOBAL, 0)

    def gauge(self, name: str, tenant: Optional[str] = None
              ) -> Optional[float]:
        return self.gauges.get(name, {}).get(
            tenant if tenant is not None else GLOBAL)

    def histogram(self, name: str, tenant: Optional[str] = None
                  ) -> Optional[Histogram]:
        return self.histograms.get(name, {}).get(
            tenant if tenant is not None else GLOBAL)

    def percentiles(self, name: str, tenant: Optional[str] = None,
                    qs: Tuple[int, ...] = (50, 90, 99)
                    ) -> Dict[str, float]:
        """Percentile summary of one histogram series (zeros when the
        series was never observed — report shapes stay stable)."""
        hist = self.histogram(name, tenant)
        if hist is None:
            return {**{f"p{q}": 0.0 for q in qs},
                    "count": 0.0, "mean": 0.0}
        return hist.percentiles(qs)

    def snapshot(self, include_timing: bool = True) -> Dict[str, Any]:
        """Nested plain-dict dump — the determinism-test comparison
        surface (``include_timing=False`` drops wall-clock histograms)
        and the JSON export body."""
        hists = {
            name: {t: h.to_dict() for t, h in sorted(series.items())}
            for name, series in sorted(self.histograms.items())
            if include_timing or name not in self._timing_names
        }
        return {
            "counters": {n: dict(sorted(s.items()))
                         for n, s in sorted(self.counters.items())},
            "gauges": {n: dict(sorted(s.items()))
                       for n, s in sorted(self.gauges.items())},
            "histograms": hists,
        }

    def to_prometheus(self, prefix: str = "guardian") -> str:
        """Prometheus text exposition of every series.  Counters become
        ``_total``, histograms the standard ``_bucket{le=}`` /``_sum`` /
        ``_count`` triple with cumulative buckets."""

        def label(tenant: str) -> str:
            return "" if tenant == GLOBAL else \
                '{tenant="%s"}' % tenant
        def label_le(tenant: str, le: str) -> str:
            if tenant == GLOBAL:
                return '{le="%s"}' % le
            return '{tenant="%s",le="%s"}' % (tenant, le)

        lines: List[str] = []
        for name, series in sorted(self.counters.items()):
            metric = f"{prefix}_{name}_total"
            lines.append(f"# TYPE {metric} counter")
            for tenant, v in sorted(series.items()):
                lines.append(f"{metric}{label(tenant)} {v}")
        for name, series in sorted(self.gauges.items()):
            metric = f"{prefix}_{name}"
            lines.append(f"# TYPE {metric} gauge")
            for tenant, v in sorted(series.items()):
                lines.append(f"{metric}{label(tenant)} {v:g}")
        for name, series in sorted(self.histograms.items()):
            metric = f"{prefix}_{name}"
            lines.append(f"# TYPE {metric} histogram")
            for tenant, h in sorted(series.items()):
                acc = 0
                for edge, c in zip(h.bounds, h.buckets):
                    acc += c
                    lines.append(
                        f"{metric}_bucket{label_le(tenant, '%g' % edge)}"
                        f" {acc}")
                lines.append(
                    f"{metric}_bucket{label_le(tenant, '+Inf')} {h.count}")
                lines.append(f"{metric}_sum{label(tenant)} {h.total:g}")
                lines.append(f"{metric}_count{label(tenant)} {h.count}")
        return "\n".join(lines) + "\n"


class TraceEvent:
    """One flight-recorder entry: ``track`` is the Perfetto thread the
    event renders on (a tenant id, the scheduler/drain tracks, or a
    per-request ``tenant:rN`` track), ``cycle`` the scheduler's
    drain-cycle stamp, ``ts_us`` wall microseconds from trace start,
    ``dur_us`` present for duration events (drain cycles, span phases).
    ``flow`` optionally attaches a Chrome flow-event record
    (``("s"|"t"|"f", flow_id)``) so e.g. a request's submit instant links
    to its span slices across tracks with a Perfetto arrow."""

    __slots__ = ("name", "track", "cycle", "ts_us", "dur_us", "args",
                 "flow")

    def __init__(self, name: str, track: str, cycle: int, ts_us: float,
                 dur_us: Optional[float] = None,
                 args: Optional[Dict[str, Any]] = None,
                 flow: Optional[Tuple[str, int]] = None):
        self.name = name
        self.track = track
        self.cycle = cycle
        self.ts_us = ts_us
        self.dur_us = dur_us
        self.args = args or {}
        self.flow = flow

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "track": self.track,
                "cycle": self.cycle, "ts_us": self.ts_us,
                "dur_us": self.dur_us, "args": dict(self.args)}


#: Perfetto track names of the manager-plane (non-tenant) timelines
SCHEDULER_TRACK = "scheduler"
DRAIN_TRACK = "drain-cycles"


class EventTrace:
    """Bounded ring buffer of :class:`TraceEvent`\\ s.

    Append is O(1) host work (the deque drops the oldest entry at
    capacity — a flight recorder, not an archive).  Timestamps come from
    ``time.perf_counter_ns`` relative to trace start, so they are
    monotonic per track by construction: every track's events are
    emitted in wall order (drain duration events live on their own
    :data:`DRAIN_TRACK` — their *start* stamps are monotonic because
    drain cycles never overlap).
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        if capacity < 1:
            raise ValueError("EventTrace capacity must be >= 1")
        self.enabled = enabled
        self.capacity = capacity
        self._events: Deque[TraceEvent] = deque(maxlen=capacity)
        self._t0 = time.perf_counter_ns()
        #: lifetime append count (ring drops are visible as
        #: ``emitted - len(events())``)
        self.emitted = 0
        #: lifetime count of events the ring silently evicted at
        #: capacity — surfaced in ``metrics_report()["trace"]`` and as a
        #: ``repro.top`` warning so an undersized ring is never mistaken
        #: for a complete trace
        self.dropped = 0

    def emit(self, name: str, track: str, cycle: int,
             dur_us: Optional[float] = None,
             ts_us: Optional[float] = None,
             flow: Optional[Tuple[str, int]] = None,
             **args: Any) -> None:
        if not self.enabled:
            return
        if ts_us is None:
            ts_us = (time.perf_counter_ns() - self._t0) / 1000.0
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(TraceEvent(name, track, cycle, ts_us,
                                       dur_us=dur_us, args=args,
                                       flow=flow))
        self.emitted += 1

    def now_us(self) -> float:
        """Wall microseconds since trace start (for callers stamping a
        duration event's start explicitly)."""
        return (time.perf_counter_ns() - self._t0) / 1000.0

    def events(self) -> List[TraceEvent]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    # -- Chrome/Perfetto export ----------------------------------------- #
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON object (the dict form — dump with
        :meth:`to_json`).  One pid ("guardian"), one tid per track in
        first-seen order, thread_name metadata rows, instant events
        (``ph: "i"``) for lifecycle transitions and complete events
        (``ph: "X"``) for drain cycles.  Loadable in ``ui.perfetto.dev``
        or ``chrome://tracing``."""
        pid = 1
        tids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "guardian"},
        }]
        body: List[Dict[str, Any]] = []
        for ev in self._events:
            tid = tids.get(ev.track)
            if tid is None:
                tid = tids[ev.track] = len(tids) + 1
                out.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": ev.track}})
            rec: Dict[str, Any] = {
                "name": ev.name, "pid": pid, "tid": tid,
                "cat": "guardian",
                "args": {"cycle": ev.cycle, **ev.args},
            }
            if ev.dur_us is not None:
                rec["ph"] = "X"
                rec["ts"] = ev.ts_us
                rec["dur"] = ev.dur_us
            else:
                rec["ph"] = "i"
                rec["ts"] = ev.ts_us
                rec["s"] = "t"
            body.append(rec)
            if ev.flow is not None:
                letter, fid = ev.flow
                frec: Dict[str, Any] = {
                    "name": "request", "cat": "guardian.flow",
                    "ph": letter, "id": fid, "pid": pid, "tid": tid,
                    "ts": ev.ts_us,
                }
                if letter == "f":
                    frec["bp"] = "e"
                body.append(frec)
        return {"traceEvents": out + body, "displayTimeUnit": "ms"}

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_chrome(), **kw)


#: the exhaustive partition of a serve request's lifetime (drain-cycle
#: clock).  ``queue``: submitted but not yet picked; ``hold``: picked
#: into a run but parked this cycle (inflight cap / no idle row);
#: ``prefill``/``decode``: on-device compute cycles; ``preempt``:
#: bypassed by a latency-critical joiner; ``stall``: blocked on the
#: paged-KV pool (page-full / compaction stall).
SPAN_PHASES: Tuple[str, ...] = (
    "queue", "hold", "prefill", "decode", "preempt", "stall")

#: the non-compute phases — their sum is a request's *slack*, the
#: quantity an SLO class budgets (``TenantClassPolicy.queue_age_budget``)
SLACK_PHASES: Tuple[str, ...] = ("queue", "hold", "preempt", "stall")


class RequestSpan:
    """One serve request's lifetime, partitioned into phases on the
    drain-cycle clock.

    The span is a sequence of half-open phase segments
    ``(phase, cycle0, cycle1, us0, us1)`` with ``cycle1`` of each equal
    to ``cycle0`` of the next, so the per-phase cycle components sum
    *exactly* to the end-to-end latency by construction — the
    reconciliation invariant tests and the macro-bench assert.  Phase
    transitions are recorded at drain-cycle boundaries by the serve
    drivers; a transition within one cycle renames the pending phase
    rather than emitting a zero-length segment.

    Pure host bookkeeping: never reads device memory, never syncs.
    Spans are created by :class:`SpanLedger` (None when telemetry is
    off — every call site guards, so off-mode is byte-identical).
    """

    __slots__ = ("tenant", "rid", "sid", "cls", "budget", "started",
                 "segments", "start_cycle", "start_us", "end_cycle",
                 "end_us", "outcome", "_phase", "_pc", "_pus")

    def __init__(self, tenant: str, rid: int, sid: int,
                 cls: Optional[str] = None,
                 budget: Optional[int] = None):
        self.tenant = tenant
        self.rid = rid
        #: ledger-unique span id — doubles as the Perfetto flow id
        self.sid = sid
        #: SLO class name ("latency_critical"/"best_effort"/None)
        self.cls = cls
        #: slack budget in drain cycles (None = unbudgeted: always
        #: attained on completion)
        self.budget = budget
        self.started = False
        self.segments: List[Tuple[str, int, int, float, float]] = []
        self.start_cycle = 0
        self.start_us = 0.0
        self.end_cycle = 0
        self.end_us = 0.0
        #: terminal state: "complete" | "evicted" | "withdrawn"
        self.outcome: Optional[str] = None
        self._phase: Optional[str] = None
        self._pc = 0
        self._pus = 0.0

    def begin(self, cycle: int, us: float) -> None:
        """Start the clock (phase ``queue``).  Paged requests with a
        future ``arrive`` stamp begin when they become visible to
        admission, not at submit — queueing they asked for is not
        queueing the system imposed."""
        self.started = True
        self.start_cycle = cycle
        self.start_us = us
        self._phase = "queue"
        self._pc = cycle
        self._pus = us

    def phase(self, name: str, cycle: int, us: float) -> None:
        """Transition to ``name`` at drain-cycle ``cycle``.  No-op when
        unstarted, finished, or already in that phase."""
        if (not self.started or self.outcome is not None
                or name == self._phase):
            return
        if cycle > self._pc:
            self.segments.append(
                (self._phase, self._pc, cycle, self._pus, us))
            self._pc = cycle
            self._pus = us
        self._phase = name

    def finish(self, outcome: str, cycle: int, us: float) -> None:
        """Stamp the terminal state and close the pending segment.  An
        unstarted span (deferred, then withdrawn/evicted before its
        clock began) closes zero-length."""
        if self.outcome is not None:
            return
        if not self.started:
            self.begin(cycle, us)
        if cycle > self._pc:
            self.segments.append(
                (self._phase, self._pc, cycle, self._pus, us))
        self.end_cycle = cycle
        self.end_us = us
        self.outcome = outcome
        self._phase = None

    @property
    def e2e_cycles(self) -> int:
        return self.end_cycle - self.start_cycle

    @property
    def e2e_us(self) -> float:
        return self.end_us - self.start_us

    def components(self) -> Dict[str, int]:
        """Per-phase drain-cycle totals.  For every finished span,
        ``sum(components().values()) == e2e_cycles`` exactly."""
        comps = {p: 0 for p in SPAN_PHASES}
        for phase, c0, c1, _us0, _us1 in self.segments:
            comps[phase] += c1 - c0
        return comps

    def slack_cycles(self) -> int:
        comps = self.components()
        return sum(comps[p] for p in SLACK_PHASES)

    def to_dict(self) -> Dict[str, Any]:
        return {"tenant": self.tenant, "rid": self.rid,
                "class": self.cls, "outcome": self.outcome,
                "e2e_cycles": self.e2e_cycles,
                "components": self.components()}


class SpanLedger:
    """Owns every :class:`RequestSpan` and folds closed spans into the
    per-tenant-class SLO attainment ledger.

    A latency-critical span *attains* its SLO when it completes with
    slack (queue+hold+preempt+stall cycles) within the class's
    ``queue_age_budget``; everything else — over-budget completions,
    evictions, withdrawals — is a violation with a cause (the dominant
    slack phase, or the terminal outcome).  Closing a span also emits
    its per-request Perfetto track (one ``X`` slice per phase segment,
    flow-linked back to the submit instant) and feeds the
    ``request_e2e_cycles`` / ``request_e2e_us`` histograms.

    All methods are None-tolerant and off-mode no-ops: with telemetry
    disabled :meth:`open` returns None and every other method returns
    immediately, so the serve hot paths stay byte-identical.
    """

    #: closed spans retained for audit (tests, macro-bench reconciliation)
    CLOSED_KEEP = 4096

    def __init__(self, tel: "Telemetry"):
        self.tel = tel
        self._open: Dict[int, RequestSpan] = {}
        self._next_id = 1
        self.closed: Deque[RequestSpan] = deque(maxlen=self.CLOSED_KEEP)
        #: class name -> {"attained", "violated", "causes": {cause: n}}
        self.classes: Dict[str, Dict[str, Any]] = {}
        #: tenant -> {"attained", "violated"} (dropped on forget_tenant)
        self.by_tenant: Dict[str, Dict[str, int]] = {}
        #: lifetime terminal-outcome totals
        self.totals: Dict[str, int] = {}

    def open_count(self) -> int:
        return len(self._open)

    def open(self, tenant: str, rid: int, cls: Optional[str] = None,
             budget: Optional[int] = None,
             defer: bool = False) -> Optional[RequestSpan]:
        """New span for request ``rid`` (None when telemetry is off).
        ``defer=True`` registers the span without starting its clock —
        :meth:`begin` starts it when the request becomes admissible."""
        if not self.tel.enabled:
            return None
        sp = RequestSpan(tenant, rid, self._next_id, cls=cls,
                         budget=budget)
        self._next_id += 1
        self._open[sp.sid] = sp
        if not defer:
            self.begin(sp)
        return sp

    def begin(self, sp: Optional[RequestSpan]) -> None:
        if sp is None or sp.started:
            return
        trace = self.tel.trace
        sp.begin(self.tel.cycle, trace.now_us())
        trace.emit("req_submit", sp.tenant, sp.start_cycle,
                   ts_us=sp.start_us, flow=("s", sp.sid), rid=sp.rid)

    def phase(self, sp: Optional[RequestSpan], name: str) -> None:
        """Transition ``sp`` at the current drain cycle (cheap no-op on
        None / unstarted / same-phase — callers don't guard)."""
        if sp is None or not sp.started or name == sp._phase:
            return
        sp.phase(name, self.tel.cycle, self.tel.trace.now_us())

    def close(self, sp: Optional[RequestSpan], outcome: str) -> None:
        """Terminal transition: fold the span into the ledger, feed the
        latency histograms, emit its Perfetto track."""
        if sp is None or sp.outcome is not None:
            return
        cycle = self.tel.cycle
        trace = self.tel.trace
        sp.finish(outcome, cycle, trace.now_us())
        self._open.pop(sp.sid, None)
        self.closed.append(sp)
        self.totals[outcome] = self.totals.get(outcome, 0) + 1

        reg = self.tel.registry
        reg.inc(f"requests_{outcome}", tenant=sp.tenant)
        reg.observe("request_e2e_cycles", float(sp.e2e_cycles),
                    tenant=sp.tenant)
        reg.observe("request_e2e_us", sp.e2e_us, tenant=sp.tenant,
                    timing=True)

        comps = sp.components()
        slack = sum(comps[p] for p in SLACK_PHASES)
        attained = (outcome == "complete"
                    and (sp.budget is None or slack <= sp.budget))
        row = self.by_tenant.get(sp.tenant)
        if row is None:
            row = self.by_tenant[sp.tenant] = {"attained": 0,
                                               "violated": 0}
        cls = sp.cls if sp.cls is not None else "unclassified"
        crow = self.classes.get(cls)
        if crow is None:
            crow = self.classes[cls] = {"attained": 0, "violated": 0,
                                        "causes": {}}
        if attained:
            reg.inc("slo_attained", tenant=sp.tenant)
            row["attained"] += 1
            crow["attained"] += 1
        else:
            reg.inc("slo_violated", tenant=sp.tenant)
            row["violated"] += 1
            crow["violated"] += 1
            cause = outcome if outcome != "complete" else \
                max(SLACK_PHASES, key=lambda p: comps[p])
            crow["causes"][cause] = crow["causes"].get(cause, 0) + 1

        track = f"{sp.tenant}:r{sp.rid}"
        first = True
        for name, c0, c1, us0, us1 in sp.segments:
            trace.emit(name, track, c0, dur_us=max(us1 - us0, 0.0),
                       ts_us=us0, cycles=c1 - c0,
                       flow=("f", sp.sid) if first else None)
            first = False
        trace.emit(f"req_{outcome}", track, cycle, rid=sp.rid,
                   e2e_cycles=sp.e2e_cycles, slack=slack,
                   flow=("f", sp.sid) if first else None)

    def forget_tenant(self, tenant_id: str) -> None:
        """Eviction path: close the departed tenant's open spans (each
        counts as a violated request with cause ``evicted``) before the
        registry drops its series, then drop the per-tenant ledger row.
        Class-level aggregates survive — fleet history, not tenant
        state."""
        for sid in [s for s, sp in self._open.items()
                    if sp.tenant == tenant_id]:
            self.close(self._open[sid], "evicted")
        self.by_tenant.pop(tenant_id, None)

    def to_dict(self) -> Dict[str, Any]:
        classes: Dict[str, Any] = {}
        for cls, row in sorted(self.classes.items()):
            total = row["attained"] + row["violated"]
            classes[cls] = {
                "attained": row["attained"],
                "violated": row["violated"],
                "attainment": row["attained"] / total if total else 1.0,
                "causes": dict(sorted(row["causes"].items())),
            }
        return {
            "classes": classes,
            "tenants": {t: dict(r)
                        for t, r in sorted(self.by_tenant.items())},
            "open_spans": len(self._open),
            "completed": self.totals.get("complete", 0),
            "evicted": self.totals.get("evicted", 0),
            "withdrawn": self.totals.get("withdrawn", 0),
        }


class Telemetry:
    """The flight-recorder facade a :class:`GuardianManager` owns.

    Bundles the :class:`MetricsRegistry` and :class:`EventTrace` behind
    one ``enabled`` switch and assembles the unified operator report.
    The manager back-reference exists only for *report-time* reads (it
    is never touched on a record path), plus the drain-cycle clock.
    """

    def __init__(self, manager: Any = None, enabled: bool = True,
                 trace_capacity: int = 65536):
        self.manager = manager
        self.enabled = enabled
        self.registry = MetricsRegistry(enabled=enabled)
        self.trace = EventTrace(capacity=trace_capacity, enabled=enabled)
        self.spans = SpanLedger(self)

    @property
    def cycle(self) -> int:
        """The scheduler's current drain-cycle counter — the logical
        clock every event is stamped with."""
        if self.manager is None:
            return 0
        return self.manager.scheduler._cycle

    def event(self, name: str, track: str,
              dur_us: Optional[float] = None,
              ts_us: Optional[float] = None, **args: Any) -> None:
        """Emit a lifecycle event stamped with the current drain cycle."""
        if not self.enabled:
            return
        self.trace.emit(name, track, self.cycle, dur_us=dur_us,
                        ts_us=ts_us, **args)

    def forget_tenant(self, tenant_id: str) -> None:
        # spans first: closing an evicted tenant's open spans writes its
        # counters, which the registry purge below then drops
        self.spans.forget_tenant(tenant_id)
        self.registry.forget_tenant(tenant_id)

    # ------------------------------------------------------------------ #
    # Legacy views (API-compatible with the pre-registry surfaces)       #
    # ------------------------------------------------------------------ #
    def violation_view(self) -> Dict[str, Any]:
        """The ``violation_report()`` body: per-tenant per-kind OOB
        counts (synchronizing — one ViolationLog snapshot), lifecycle
        states, transfer violations, quarantine events."""
        from repro.core.quarantine import TenantState
        from repro.core.violations import KIND_NAMES

        mgr = self.manager
        snap = mgr.violog.snapshot()
        tenants: Dict[str, Dict[str, Any]] = {}
        for t in mgr.violog.tenants():
            counts = mgr.violog.counts(t, snap=snap)
            state = mgr.quarantine.state_of(t)
            tenants[t] = {
                **counts,
                "total": sum(counts.values()),
                "state": state.value if state
                else TenantState.ACTIVE.value,
            }
        for rec in mgr.quarantine.machine.records():
            if rec.tenant_id in tenants:
                continue
            counts = {k: rec.final_counts.get(k, 0) for k in KIND_NAMES}
            tenants[rec.tenant_id] = {
                **counts,
                "total": sum(counts.values()),
                "state": rec.state.value,
            }
        return {
            "tenants": tenants,
            "transfer_violations": list(mgr.violations),
            "events": list(mgr.quarantine.events),
        }

    def jit_cache_view(self) -> Dict[str, Any]:
        """The ``jit_cache_stats()`` body: occupancy + evictions of every
        LRU-bounded compiled cache (host dict sizes — never a sync)."""
        from repro.core.scheduler import LRUCache

        mgr = self.manager
        per_kernel = {name: len(e.jit_cache)
                      for name, e in mgr.pointer_to_symbol.items()}
        return {
            "capacity": mgr.jit_cache_capacity,
            "entries": sum(per_kernel.values()),
            "per_kernel": per_kernel,
            "evictions": sum(e.jit_cache.evictions
                             for e in mgr.pointer_to_symbol.values()
                             if isinstance(e.jit_cache, LRUCache)),
            "fused_capacity": mgr.scheduler._fused_cache.capacity,
            "fused_entries": len(mgr.scheduler._fused_cache),
            "fused_evictions": mgr.scheduler._fused_cache.evictions,
        }

    # ------------------------------------------------------------------ #
    # The unified report                                                 #
    # ------------------------------------------------------------------ #
    def report(self) -> Dict[str, Any]:
        """One dict unifying the five legacy surfaces plus the registry:
        per-tenant rows (state, policy, SLO class, weight, extent,
        utilization, queue-age p50/p90/p99, violation counts), the
        scheduler/launch
        summaries, the drain-cycle wall-time histogram, jit-cache and
        elastic stats.  Synchronizing (the violation view snapshots the
        device log) — an operator surface, never a hot-path call."""
        mgr = self.manager
        vio = self.violation_view()
        stats = mgr.scheduler.stats
        tenants: Dict[str, Dict[str, Any]] = {}
        for t in sorted(mgr.bounds.tenants()):
            part = mgr.bounds.lookup(t)
            sub = mgr._suballoc.get(t)
            state = mgr.quarantine.state_of(t)
            util = self.registry.gauge("arena_utilization", tenant=t)
            cp = mgr.class_policy_of(t)
            tenants[t] = {
                "state": state.value if state else "active",
                "policy": mgr.policy_of(t).value,
                "class": cp.tenant_class.value if cp is not None else None,
                "weight": mgr.weight_of(t),
                "partition": {"base": part.base, "size": part.size},
                "live_slots": sub.live_bytes() if sub is not None
                else None,
                "utilization": util,
                # serving-plane gauges (continuous driver): batch rows
                # currently held and fraction of the page extent in use
                "inflight": self.registry.gauge("serve_inflight",
                                                tenant=t),
                "page_occupancy": self.registry.gauge("page_occupancy",
                                                      tenant=t),
                "queue_age": self.registry.percentiles(
                    "queue_age_cycles", tenant=t),
                # request-span ledger: end-to-end latency percentiles
                # (wall us) and SLO attainment counts for the serving
                # plane (zeros/absent for non-serving tenants)
                "latency": self.registry.percentiles(
                    "request_e2e_us", tenant=t),
                "slo": self.spans.by_tenant.get(
                    t, {"attained": 0, "violated": 0}),
                "violations": vio["tenants"].get(t, {}),
            }
        return {
            "tenants": tenants,
            "scheduler": {
                **stats.summary(),
                "queue_age": stats.queue_age_percentiles(),
                "queue_age_by_class":
                    stats.queue_age_percentiles_by_class(),
                "fused_width": self.registry.percentiles(
                    "fused_step_width"),
            },
            "drain": self.registry.percentiles("drain_cycle_us"),
            "drain_cycles": self.registry.counter("drain_cycles"),
            "launch": mgr.launch_stats.summary(),
            "jit_cache": self.jit_cache_view(),
            "elastic": {
                **mgr.elastic.stats,
                "waitlist": len(mgr.elastic.waitlist),
                "waitlist_age": self.registry.percentiles(
                    "waitlist_age_cycles"),
            },
            "memory": mgr.memory_usage(),
            "violations": vio,
            "counters": {n: dict(sorted(s.items()))
                         for n, s in sorted(
                             self.registry.counters.items())},
            "gauges": {n: dict(sorted(s.items()))
                       for n, s in sorted(self.registry.gauges.items())},
            "slo": self.spans.to_dict(),
            "trace": {"events": len(self.trace),
                      "emitted": self.trace.emitted,
                      "dropped": self.trace.dropped,
                      "capacity": self.trace.capacity},
        }
